//! Multi-process adapter-store stress: concurrent *processes* hammer
//! `publish_merged` on one shared store directory through the real
//! binary (`adapters stress-publish`), so the publish race crosses
//! process boundaries — threads share an address space and can hide
//! ordering a second process would expose. Before the store lock, the
//! last writer's index rewrite silently dropped every other writer's
//! rows; this test pins the fix: zero lost index entries.

use std::path::PathBuf;
use std::process::Command;

use qrlora::store::{AdapterKey, Registry};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("qrlora_fleet_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn concurrent_publisher_processes_lose_no_index_entries() {
    let dir = tmp_dir("stress_publish");
    let exe = env!("CARGO_BIN_EXE_qrlora");
    let writers = 4usize;
    let records = 8usize;
    let children: Vec<_> = (0..writers)
        .map(|w| {
            Command::new(exe)
                .args(["adapters", "stress-publish"])
                .args(["--adapter-store", &dir.display().to_string()])
                .args(["--records", &records.to_string()])
                .args(["--writer-id", &w.to_string()])
                .spawn()
                .expect("spawn stress-publish writer")
        })
        .collect();
    for mut child in children {
        let status = child.wait().unwrap();
        assert!(status.success(), "a stress-publish writer failed: {status}");
    }

    let reg = Registry::open(&dir).unwrap();
    assert_eq!(
        reg.len(),
        writers * records,
        "concurrent publishes lost index entries (last-writer-wins regression)"
    );
    for w in 0..writers {
        for j in 0..records {
            let key = AdapterKey::new("tiny", "stress", &format!("t{j}"), w as u64);
            assert!(reg.lookup(&key).is_some(), "missing {key:?}");
        }
    }
    // Every surviving entry must also point at an intact record file.
    assert!(reg.verify().iter().all(|r| r.result.is_ok()));
}
