//! SIMD kernel parity property suite for `kernels::Kernels`.
//!
//! * **Strict (default) mode** is bit-exact: every backend must match the
//!   scalar reference bit for bit, at the primitive level and through the
//!   tensor ops, over tall/wide/square/ragged/empty shapes.
//! * **Relaxed mode** (`--simd-relaxed`) may re-associate dot reductions,
//!   but must stay within 1e-5 relative error of strict mode.
//! * The int8 forward product's integer-accumulate path (the one
//!   documented strict-mode exception) must stay inside its analytic
//!   activation-quantization bound on outlier-heavy matrices and stay
//!   bit-identical across thread counts; the backward int8 product is
//!   exact on every backend.
//! * The forced-scalar backend must reproduce the pre-kernels inline
//!   loops exactly (pins `QRLORA_SIMD=scalar` ≡ pre-refactor bits).
//! * Model level: padded-batch logits are unaffected by pad content, and
//!   strict mode is bit-identical scalar-vs-detected through full
//!   `eval_forward`/`train_step` passes.
//!
//! Matmul shapes come from `kernels::PARITY_SHAPES`, shared with
//! `rust/tests/pool_determinism.rs` so the thread-count and simd-mode
//! matrices compose over the same cases.

use std::collections::BTreeMap;

use qrlora::data::HeadKind;
use qrlora::kernels::{self, Kernels, PARITY_SHAPES};
use qrlora::model::host::{
    eval_forward, train_step, FrozenMap, FrozenValue, MethodKind, TaskBatchRef,
};
use qrlora::quant::{self, QuantTensor, QUANT_GROUP_ROWS};
use qrlora::runtime::{Manifest, Preset, Role, StateLayout};
use qrlora::tensor::Tensor;
use qrlora::util::pool;
use qrlora::util::rng::Rng;

/// Slice lengths straddling every SIMD width boundary (8/16/32 lanes) plus
/// ragged tails and the empty slice.
const LENS: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 15, 16, 31, 33, 64, 100, 257];

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: bit mismatch at {i}: {x} vs {y}"
        );
    }
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

fn randq(n: usize, seed: usize) -> Vec<i8> {
    (0..n).map(|i| (((i * 37 + seed * 13 + 11) % 255) as i32 - 127) as i8).collect()
}

// ---- strict-mode exact-bits parity ------------------------------------

#[test]
fn strict_primitives_bit_match_scalar_on_every_backend() {
    let s = Kernels::scalar();
    let v = Kernels::detected(false);
    for &len in LENS {
        let mut rng = Rng::new(1000 + len as u64);
        let a = randv(&mut rng, len);
        let b = randv(&mut rng, len);
        let b4: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut rng, len)).collect();
        let q = randq(len, len);

        assert_eq!(s.dot(&a, &b).to_bits(), v.dot(&a, &b).to_bits(), "dot len={len}");
        assert_eq!(s.dot_seq(&a, &b).to_bits(), v.dot_seq(&a, &b).to_bits(), "dot_seq len={len}");
        let d4s = s.dot4(&a, &b4[0], &b4[1], &b4[2], &b4[3]);
        let d4v = v.dot4(&a, &b4[0], &b4[1], &b4[2], &b4[3]);
        for (i, (x, y)) in d4s.iter().zip(&d4v).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "dot4[{i}] len={len}");
        }
        // dot4 lanes must equal the single-dot result exactly.
        for (i, bi) in b4.iter().enumerate() {
            assert_eq!(d4s[i].to_bits(), s.dot(&a, bi).to_bits(), "dot4 vs dot lane {i}");
        }

        let base = randv(&mut rng, len);
        let mut ys = base.clone();
        let mut yv = base.clone();
        s.axpy(1.37, &a, &mut ys);
        v.axpy(1.37, &a, &mut yv);
        assert_bits_eq(&ys, &yv, &format!("axpy len={len}"));
        s.vadd(&a, &mut ys);
        v.vadd(&a, &mut yv);
        assert_bits_eq(&ys, &yv, &format!("vadd len={len}"));
        s.vmul(&b, &mut ys);
        v.vmul(&b, &mut yv);
        assert_bits_eq(&ys, &yv, &format!("vmul len={len}"));
        s.vmuladd(&a, &b, &mut ys);
        v.vmuladd(&a, &b, &mut yv);
        assert_bits_eq(&ys, &yv, &format!("vmuladd len={len}"));
        s.axpy_i8(-0.71, &q, &mut ys);
        v.axpy_i8(-0.71, &q, &mut yv);
        assert_bits_eq(&ys, &yv, &format!("axpy_i8 len={len}"));
        s.scale_i8(0.031, &q, &mut ys);
        v.scale_i8(0.031, &q, &mut yv);
        assert_bits_eq(&ys, &yv, &format!("scale_i8 len={len}"));
    }
}

#[test]
fn strict_layernorm_rows_bit_match_scalar_on_every_backend() {
    let s = Kernels::scalar();
    let v = Kernels::detected(false);
    for &d in &[1usize, 5, 8, 33, 64, 100] {
        let rows = 3usize;
        let mut rng = Rng::new(2000 + d as u64);
        let x = randv(&mut rng, rows * d);
        let g = randv(&mut rng, d);
        let b = randv(&mut rng, d);
        let run_fwd = |k: Kernels| {
            let mut y = vec![0f32; rows * d];
            let mut xhat = vec![0f32; rows * d];
            let mut rstd = vec![0f32; rows];
            k.ln_fwd_rows(&x, d, &g, &b, &mut y, &mut xhat, &mut rstd);
            (y, xhat, rstd)
        };
        let (ys, xs, rs) = run_fwd(s);
        let (yv, xv, rv) = run_fwd(v);
        assert_bits_eq(&ys, &yv, &format!("ln_fwd y d={d}"));
        assert_bits_eq(&xs, &xv, &format!("ln_fwd xhat d={d}"));
        assert_bits_eq(&rs, &rv, &format!("ln_fwd rstd d={d}"));

        let dy = randv(&mut rng, rows * d);
        let run_bwd = |k: Kernels| {
            let mut dx = vec![0f32; rows * d];
            k.ln_bwd_dx_rows(&dy, &xs, &rs, &g, d, &mut dx);
            dx
        };
        assert_bits_eq(&run_bwd(s), &run_bwd(v), &format!("ln_bwd dx d={d}"));
    }
}

#[test]
fn strict_tensor_ops_bit_match_scalar_backend() {
    let simd = Kernels::detected(false);
    for &(m, k, n) in PARITY_SHAPES {
        let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let bt = Tensor::randn(&[n, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let c = Tensor::randn(&[m, n], &mut rng, 1.0);
        let run = |kern: Kernels| {
            kernels::with_kernels(kern, || (a.matmul_t(&bt), a.matmul(&b), a.t_matmul(&c)))
        };
        let (s_mt, s_mm, s_tm) = run(Kernels::scalar());
        let (v_mt, v_mm, v_tm) = run(simd);
        assert_bits_eq(&s_mt.data, &v_mt.data, &format!("matmul_t {m}x{k}x{n}"));
        assert_bits_eq(&s_mm.data, &v_mm.data, &format!("matmul {m}x{k}x{n}"));
        assert_bits_eq(&s_tm.data, &v_tm.data, &format!("t_matmul {m}x{k}x{n}"));
    }
}

#[test]
fn empty_inputs_are_no_ops_on_every_backend() {
    for kern in [Kernels::scalar(), Kernels::detected(false), Kernels::detected(true)] {
        assert_eq!(kern.dot(&[], &[]), 0.0);
        assert_eq!(kern.dot_seq(&[], &[]), 0.0);
        assert_eq!(kern.dot4(&[], &[], &[], &[], &[]), [0.0; 4]);
        let mut y: [f32; 0] = [];
        kern.axpy(2.0, &[], &mut y);
        kern.vadd(&[], &mut y);
        kern.vmul(&[], &mut y);
        kern.vmuladd(&[], &[], &mut y);
        kern.axpy_i8(1.0, &[], &mut y);
        kern.scale_i8(1.0, &[], &mut y);
        let mut out: [f32; 0] = [];
        kern.matmul_xw_t(&[], &[], 4, 0, &mut out); // n == 0
        kern.matmul_xw_t(&[], &[0.0; 12], 4, 3, &mut out); // zero rows
        kern.matmul_xt_y(&[], &[], 0, 4, 3, 0, &mut out); // m == 0
        kern.matmul_xw_q(&[], 4, &[], &[1.0], 8, 0, &mut out);
        kern.matmul_dyw_t_q(&[], 3, &[], &[1.0], 8, 0, &mut out);
        kern.softmax_rows(&mut [], 0, 0);
        kern.gelu_fwd_rows(&[], 3, None, &mut [], &mut []);
        kern.gelu_bwd(&[], &[], &[], &mut []);
    }
}

// ---- relaxed-mode error bound -----------------------------------------

#[test]
fn relaxed_dots_within_rel_error_of_strict() {
    let strict = Kernels::scalar();
    let relaxed = Kernels::detected(true);
    for &len in LENS {
        let mut rng = Rng::new(3000 + len as u64);
        let mut a = randv(&mut rng, len);
        let b = randv(&mut rng, len);
        // Mixed magnitudes so re-association actually moves bits.
        for (i, v) in a.iter_mut().enumerate() {
            if i % 5 == 0 {
                *v *= 100.0;
            }
        }
        let denom: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        let bound = 1e-5 * denom.max(1e-3);
        let (ss, rr) = (strict.dot(&a, &b), relaxed.dot(&a, &b));
        assert!((ss - rr).abs() <= bound, "dot len={len}: |{ss} - {rr}| > {bound}");
        let (ss, rr) = (strict.dot_seq(&a, &b), relaxed.dot_seq(&a, &b));
        assert!((ss - rr).abs() <= bound, "dot_seq len={len}: |{ss} - {rr}| > {bound}");
    }
}

#[test]
fn relaxed_matmul_within_rel_error_of_strict() {
    let (m, k, n) = (64usize, 64usize, 64usize);
    let mut rng = Rng::new(404);
    let a = Tensor::randn(&[m, k], &mut rng, 1.0);
    let bt = Tensor::randn(&[n, k], &mut rng, 1.0);
    let s = kernels::with_kernels(Kernels::scalar(), || a.matmul_t(&bt));
    let r = kernels::with_kernels(Kernels::detected(true), || a.matmul_t(&bt));
    for i in 0..m {
        for j in 0..n {
            let denom: f32 = a.row(i).iter().zip(bt.row(j)).map(|(x, y)| (x * y).abs()).sum();
            let bound = 1e-5 * denom.max(1e-3);
            let err = (s.at(i, j) - r.at(i, j)).abs();
            assert!(err <= bound, "({i},{j}): err {err} > bound {bound}");
        }
    }
}

// ---- int8 integer-accumulate path -------------------------------------

/// The integer path quantizes each activation row with the same symmetric
/// absmax rule the kernel uses; its per-element deviation from the scalar
/// fused-dequant reference is bounded by the activation rounding error
/// `0.5·sx·scale(j)·Σ_e|q[j,e]|` plus f32 rounding slack. Outlier-heavy
/// weight rows make the per-group scales differ wildly, which is exactly
/// where a sloppy integer path would blow past the bound.
#[test]
fn int8_integer_path_within_analytic_bound_on_outliers() {
    let (m, k, n) = (9usize, 96usize, 40usize);
    let mut rng = Rng::new(77);
    let x = Tensor::randn(&[m, k], &mut rng, 1.0);
    let mut wt = Tensor::randn(&[n, k], &mut rng, 0.5);
    for j in (0..n).step_by(7) {
        for v in wt.row_mut(j) {
            *v *= 100.0;
        }
    }
    let wq = QuantTensor::quantize(&wt, QUANT_GROUP_ROWS);
    let reference = kernels::with_kernels(Kernels::scalar(), || quant::matmul_xw_q(&x, &wq));
    let integer = kernels::with_kernels(Kernels::detected(false), || quant::matmul_xw_q(&x, &wq));
    for r in 0..m {
        let absmax = x.row(r).iter().fold(0f32, |mx, v| mx.max(v.abs()));
        let sx = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        for j in 0..n {
            let qsum: f32 = wq.row(j).iter().map(|&q| (q as i32).abs() as f32).sum();
            let slack = 1e-3 * reference.at(r, j).abs().max(1.0);
            let bound = 0.5 * sx * wq.scale_of_row(j) * qsum + slack;
            let err = (reference.at(r, j) - integer.at(r, j)).abs();
            assert!(err <= bound, "({r},{j}): err {err} > bound {bound}");
        }
    }
}

#[test]
fn int8_paths_bit_identical_across_threads_and_exact_backward() {
    let mut rng = Rng::new(88);
    let x = Tensor::randn(&[64, 128], &mut rng, 1.0);
    let w = Tensor::randn(&[128, 96], &mut rng, 1.0);
    let wq = QuantTensor::quantize(&w.t(), QUANT_GROUP_ROWS);
    let dy = Tensor::randn(&[64, 96], &mut rng, 1.0);
    // Integer accumulation is exact, so the forward product must be
    // bit-stable under any thread partition on every backend.
    for kern in [Kernels::scalar(), Kernels::detected(false)] {
        let tag = kern.describe();
        kernels::with_kernels(kern, || {
            let fwd1 = pool::with_threads(1, || quant::matmul_xw_q(&x, &wq));
            let bwd1 = pool::with_threads(1, || quant::matmul_dyw_t_q(&dy, &wq));
            for t in [2usize, 5] {
                let fwd = pool::with_threads(t, || quant::matmul_xw_q(&x, &wq));
                let bwd = pool::with_threads(t, || quant::matmul_dyw_t_q(&dy, &wq));
                assert_bits_eq(&fwd1.data, &fwd.data, &format!("matmul_xw_q t={t} [{tag}]"));
                assert_bits_eq(&bwd1.data, &bwd.data, &format!("matmul_dyw_t_q t={t} [{tag}]"));
            }
        });
    }
    // The backward product never quantizes activations: exact on every
    // backend in both modes.
    let b_s = kernels::with_kernels(Kernels::scalar(), || quant::matmul_dyw_t_q(&dy, &wq));
    let b_v = kernels::with_kernels(Kernels::detected(true), || quant::matmul_dyw_t_q(&dy, &wq));
    assert_bits_eq(&b_s.data, &b_v.data, "matmul_dyw_t_q scalar vs detected+relaxed");
}

// ---- forced scalar pins the pre-kernels bits --------------------------

/// Verbatim reimplementation of the pre-kernels `tensor::dot` (four
/// independent accumulators, `(s0+s1)+(s2+s3)` combine, serial tail).
fn legacy_dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let mut acc = [0f32; 4];
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Verbatim reimplementation of the pre-kernels `quant::dot_i8`.
fn legacy_dot_i8(a: &[f32], b: &[i8]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let mut acc = [0f32; 4];
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i] as f32;
        acc[1] += a[i + 1] * b[i + 1] as f32;
        acc[2] += a[i + 2] * b[i + 2] as f32;
        acc[3] += a[i + 3] * b[i + 3] as f32;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..n {
        s += a[i] * b[i] as f32;
    }
    s
}

#[test]
fn forced_scalar_reproduces_pre_kernels_bits() {
    for &(m, k, n) in &[(7usize, 33usize, 5usize), (64, 64, 64), (3, 257, 9)] {
        let mut rng = Rng::new((m * 131 + k * 17 + n) as u64);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let bt = Tensor::randn(&[n, k], &mut rng, 1.0);
        let got = kernels::with_kernels(Kernels::scalar(), || a.matmul_t(&bt));
        for i in 0..m {
            for j in 0..n {
                let want = legacy_dot(a.row(i), bt.row(j));
                assert_eq!(got.at(i, j).to_bits(), want.to_bits(), "matmul_t ({i},{j})");
            }
        }
        let wq = QuantTensor::quantize(&bt, QUANT_GROUP_ROWS);
        let gotq = kernels::with_kernels(Kernels::scalar(), || quant::matmul_xw_q(&a, &wq));
        for i in 0..m {
            for j in 0..n {
                let want = wq.scale_of_row(j) * legacy_dot_i8(a.row(i), wq.row(j));
                assert_eq!(gotq.at(i, j).to_bits(), want.to_bits(), "matmul_xw_q ({i},{j})");
            }
        }
    }
}

// ---- model level -------------------------------------------------------

/// Same synthetic setup as `pool_determinism.rs` (test binaries cannot
/// share a module, so the few lines are duplicated).
fn setup(key: &str) -> (Preset, StateLayout, Vec<f32>, FrozenMap) {
    let m = Manifest::builtin();
    let a = m.artifact(key).unwrap();
    let p = m.preset(&a.preset).unwrap().clone();
    let layout = a.layout().unwrap().clone();
    let mut rng = Rng::new(31);
    let mut state = vec![0f32; layout.total];
    for f in &layout.params {
        for i in 0..f.numel() {
            state[f.offset + i] = rng.normal() * 0.05;
        }
    }
    let mut frozen: FrozenMap = BTreeMap::new();
    for (_, t) in a.inputs_with_role(Role::Frozen) {
        let data: Vec<f32> = if t.name.ends_with("/mask") {
            vec![1.0; t.numel()]
        } else {
            (0..t.numel()).map(|_| rng.normal() * 0.1).collect()
        };
        frozen.insert(t.name.clone(), FrozenValue::dense(Tensor::from_vec(&t.shape, data)));
    }
    (p, layout, state, frozen)
}

/// Padded positions (attn_mask 0.0) must not influence classification
/// logits: the masked softmax skips their keys exactly, the padded-row
/// GELU skip leaves their activations zero, and the Cls head pools
/// position 0 only. Scribbling junk token ids into every padded slot must
/// leave the logits bit-identical.
#[test]
fn padded_batch_logits_unchanged_by_pad_content() {
    let (p, layout, state, frozen) = setup("tiny/train_step_qrlora_cls");
    let bs = p.batch * p.max_seq;
    let mut ids: Vec<i32> = (0..bs).map(|i| ((i * 7 + 2) % p.vocab) as i32).collect();
    let type_ids = vec![0i32; bs];
    let attn_mask: Vec<f32> =
        (0..bs).map(|i| if i % p.max_seq < p.max_seq - 3 { 1.0 } else { 0.0 }).collect();
    let labels: Vec<i32> = (0..p.batch).map(|i| (i % 2) as i32).collect();
    let class_mask = vec![1.0f32; p.n_classes];
    let example_w = vec![1.0f32; p.batch];
    let logits = |ids: &[i32]| {
        let batch = TaskBatchRef {
            input_ids: ids,
            type_ids: &type_ids,
            attn_mask: &attn_mask,
            labels_i32: &labels,
            labels_f32: &[],
            class_mask: &class_mask,
            example_w: &example_w,
        };
        eval_forward(&p, MethodKind::QrLora, HeadKind::Cls, &layout, &state, &frozen, &batch)
    };
    let base = logits(&ids);
    for (id, &mv) in ids.iter_mut().zip(&attn_mask) {
        if mv == 0.0 {
            *id = ((*id as usize * 31 + 17) % p.vocab) as i32;
        }
    }
    let scribbled = logits(&ids);
    assert_bits_eq(&base, &scribbled, "padded-token content leaked into logits");
}

#[test]
fn model_steps_bit_identical_scalar_vs_detected_strict() {
    let (p, layout, state, frozen) = setup("tiny/train_step_lora_cls");
    let bs = p.batch * p.max_seq;
    let ids: Vec<i32> = (0..bs).map(|i| ((i * 7 + 2) % p.vocab) as i32).collect();
    let type_ids = vec![0i32; bs];
    // Padded tail so the masked softmax/GELU paths run under both
    // backends.
    let attn_mask: Vec<f32> =
        (0..bs).map(|i| if i % p.max_seq < p.max_seq - 3 { 1.0 } else { 0.0 }).collect();
    let labels: Vec<i32> = (0..p.batch).map(|i| (i % 2) as i32).collect();
    let class_mask = vec![1.0f32; p.n_classes];
    let example_w = vec![1.0f32; p.batch];
    let batch = TaskBatchRef {
        input_ids: &ids,
        type_ids: &type_ids,
        attn_mask: &attn_mask,
        labels_i32: &labels,
        labels_f32: &[],
        class_mask: &class_mask,
        example_w: &example_w,
    };
    let (mk, hk) = (MethodKind::Lora, HeadKind::Cls);
    let run = |kern: Kernels| {
        kernels::with_kernels(kern, || {
            let st = train_step(&p, mk, hk, &layout, &state, &frozen, &batch, 1e-3, 1.0);
            let logits = eval_forward(&p, mk, hk, &layout, &state, &frozen, &batch);
            (st, logits)
        })
    };
    let (st_s, lg_s) = run(Kernels::scalar());
    let (st_v, lg_v) = run(Kernels::detected(false));
    assert_bits_eq(&st_s, &st_v, "train_step scalar vs detected");
    assert_bits_eq(&lg_s, &lg_v, "eval_forward scalar vs detected");
}
