//! Integration: manifest-driven artifact loading + execution through the
//! backend abstraction, checked against the host-side tensor math.
//!
//! Runs against `HostBackend` by default — hermetic, no `make artifacts`
//! needed. The `pjrt_parity` module (cargo feature `pjrt`, `#[ignore]` by
//! default) compares host vs device outputs to ≤1e-3 when real PJRT
//! artifacts are present.

use qrlora::runtime::{
    create_backend, Backend, BackendChoice, Buffer, DType, HostBackend, HostTensor, Manifest, Role,
};
use qrlora::tensor::Tensor;
use qrlora::util::rng::Rng;

fn backend() -> HostBackend {
    HostBackend::new()
}

#[test]
fn kernel_base_matches_host_matmul() {
    let rt = backend();
    let exe = rt.load("tiny/kernel_base").unwrap();
    let spec = &exe.spec;
    let (m, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let n = spec.inputs[1].shape[1];

    let mut rng = Rng::new(42);
    let x = Tensor::randn(&[m, k], &mut rng, 1.0);
    let w = Tensor::randn(&[k, n], &mut rng, 0.5);

    let xb = rt.upload_f32(&x.data, &[m, k]).unwrap();
    let wb = rt.upload_f32(&w.data, &[k, n]).unwrap();
    let outs = rt.execute(&exe, &[&xb, &wb]).unwrap();
    assert_eq!(outs.len(), 1);
    let got = rt.download_f32(&outs[0]).unwrap();
    let want = x.matmul(&w);
    let got = Tensor::from_vec(&[m, n], got);
    assert!(
        got.max_abs_diff(&want) < 1e-3,
        "backend/host mismatch: {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn kernel_adapter_matches_host_fused() {
    let rt = backend();
    let exe = rt.load("tiny/kernel_adapter").unwrap();
    let spec = &exe.spec;
    let (m, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let n = spec.inputs[1].shape[1];
    let r = spec.inputs[2].shape[1];

    let mut rng = Rng::new(43);
    let x = Tensor::randn(&[m, k], &mut rng, 1.0);
    let w = Tensor::randn(&[k, n], &mut rng, 0.5);
    let q = Tensor::randn(&[k, r], &mut rng, 0.5);
    let rr = Tensor::randn(&[r, n], &mut rng, 0.5);
    let lam: Vec<f32> = (0..r).map(|_| rng.normal() * 0.1).collect();

    // Host reference: x@w + ((x@q)*lam)@rr
    let xq = x.matmul(&q);
    let mut scaled = xq.clone();
    for i in 0..m {
        for j in 0..r {
            scaled.set(i, j, scaled.at(i, j) * lam[j]);
        }
    }
    let mut want = x.matmul(&w);
    want.add_assign(&scaled.matmul(&rr));

    let args = [
        rt.upload_f32(&x.data, &[m, k]).unwrap(),
        rt.upload_f32(&w.data, &[k, n]).unwrap(),
        rt.upload_f32(&q.data, &[k, r]).unwrap(),
        rt.upload_f32(&rr.data, &[r, n]).unwrap(),
        rt.upload_f32(&lam, &[r]).unwrap(),
    ];
    let refs: Vec<&Buffer> = args.iter().collect();
    let outs = rt.execute(&exe, &refs).unwrap();
    let got = Tensor::from_vec(&[m, n], rt.download_f32(&outs[0]).unwrap());
    assert!(
        got.max_abs_diff(&want) < 1e-2,
        "backend/host mismatch: {}",
        got.max_abs_diff(&want)
    );
}

/// Build zero-ish host inputs for every non-state input of a step artifact.
fn default_inputs(
    rt: &dyn Backend,
    spec: &qrlora::runtime::ArtifactSpec,
    rng: &mut Rng,
) -> Vec<(String, Buffer)> {
    let mut out = Vec::new();
    for t in &spec.inputs {
        if t.role == Role::State {
            continue;
        }
        let buf = match t.dtype {
            DType::I32 => {
                let hi: i32 = if t.name.contains("input_ids") { 64 } else { 2 };
                let v: Vec<i32> = (0..t.numel()).map(|_| rng.below(hi as usize) as i32).collect();
                rt.upload_i32(&v, &t.shape).unwrap()
            }
            DType::F32 => {
                let v: Vec<f32> = if t.name == "lr" {
                    vec![1e-3]
                } else if t.name == "t" {
                    vec![1.0]
                } else if t.name.ends_with("/mask")
                    || t.name.contains("attn_mask")
                    || t.name.contains("class_mask")
                    || t.name.contains("example_w")
                {
                    vec![1.0; t.numel()]
                } else {
                    (0..t.numel()).map(|_| rng.normal() * 0.05).collect()
                };
                rt.upload_f32(&v, &t.shape).unwrap()
            }
        };
        out.push((t.name.clone(), buf));
    }
    out
}

#[test]
fn train_step_qrlora_runs_and_loss_improves() {
    let rt = backend();
    let exe = rt.load("tiny/train_step_qrlora_cls").unwrap();
    let spec = exe.spec.clone();
    let layout = spec.layout().unwrap();

    let mut rng = Rng::new(7);
    // init state: small random params, zero moments+metrics.
    let mut state = vec![0f32; layout.total];
    for f in &layout.params {
        for i in 0..f.numel() {
            state[f.offset + i] = rng.normal() * 0.05;
        }
    }
    let mut state_buf = rt.upload_f32(&state, &[layout.total]).unwrap();
    let rest = default_inputs(&rt, &spec, &mut rng);
    let metrics_exe = rt.load("tiny/metrics_qrlora_cls").unwrap();

    let mut losses = Vec::new();
    for step in 1..=8 {
        let t_buf = rt.upload_scalar(step as f32).unwrap();
        let mut args: Vec<&Buffer> = Vec::new();
        for t in &spec.inputs {
            if t.role == Role::State {
                args.push(&state_buf);
            } else if t.name == "t" {
                args.push(&t_buf);
            } else {
                args.push(&rest.iter().find(|(n, _)| n == &t.name).unwrap().1);
            }
        }
        let mut outs = rt.execute(&exe, &args).unwrap();
        drop(args);
        state_buf = outs.swap_remove(0);
        let loss_field = layout.metric("loss").unwrap();
        assert_eq!(loss_field.offset, 0, "loss must lead the metrics head");
        let head = rt.read_metrics(&metrics_exe, &state_buf).unwrap();
        assert!(head[0].is_finite(), "step {step}: loss {}", head[0]);
        losses.push(head[0]);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not improve: {losses:?}"
    );
}

#[test]
fn frozen_cache_invalidates_when_frozen_input_changes() {
    // The host backend caches frozen-input Tensor conversions across
    // execute() calls, keyed on buffer identity + fingerprint. Hot-swapping
    // a frozen buffer between steps must invalidate the cached tensor; an
    // unchanged buffer must keep serving identical results.
    let rt = backend();
    let exe = rt.load("tiny/eval_fwd_qrlora_cls").unwrap();
    let spec = exe.spec.clone();
    let layout = spec.layout().unwrap();

    let mut rng = Rng::new(991);
    let mut state = vec![0f32; layout.total];
    for f in &layout.params {
        for i in 0..f.numel() {
            state[f.offset + i] = rng.normal() * 0.05;
        }
    }
    let state_buf = rt.upload_f32(&state, &[layout.total]).unwrap();
    let mut inputs = default_inputs(&rt, &spec, &mut rng);

    fn run(
        bk: &dyn Backend,
        exe: &qrlora::runtime::Executable,
        state_buf: &Buffer,
        inputs: &[(String, Buffer)],
    ) -> Vec<f32> {
        let mut args: Vec<&Buffer> = Vec::new();
        for t in &exe.spec.inputs {
            if t.role == Role::State {
                args.push(state_buf);
            } else {
                args.push(&inputs.iter().find(|(n, _)| n == &t.name).unwrap().1);
            }
        }
        let outs = bk.execute(exe, &args).unwrap();
        bk.download_f32(&outs[0]).unwrap()
    }

    let l1 = run(&rt, &exe, &state_buf, &inputs);
    // Second call with the very same buffers goes through the cache-hit
    // path and must be exact.
    let l1_again = run(&rt, &exe, &state_buf, &inputs);
    assert_eq!(l1, l1_again, "cache-hit path must reproduce the first call");

    // Hot-swap one frozen QR factor with freshly uploaded, different data.
    let tname = spec
        .inputs_with_role(Role::Frozen)
        .map(|(_, t)| t.name.clone())
        .find(|n| n.ends_with("/Q"))
        .expect("qrlora eval must carry a frozen Q factor");
    for (n, b) in inputs.iter_mut() {
        if n == &tname {
            let t = spec.inputs.iter().find(|t| &t.name == n).unwrap();
            let v: Vec<f32> = (0..t.numel()).map(|_| rng.normal() * 0.3).collect();
            *b = rt.upload_f32(&v, &t.shape).unwrap();
        }
    }
    let l2 = run(&rt, &exe, &state_buf, &inputs);
    assert_ne!(l1, l2, "a changed frozen input must change eval output");

    // A fresh backend (empty cache) fed the identical buffers must agree
    // exactly — i.e. the cached path really used the new values.
    let fresh = backend();
    let fexe = fresh.load("tiny/eval_fwd_qrlora_cls").unwrap();
    let l2_fresh = run(&fresh, &fexe, &state_buf, &inputs);
    assert_eq!(l2, l2_fresh, "cached path diverged from a cold-cache run");
}

#[test]
fn metrics_slice_matches_full_download() {
    // Pin the metrics-head protocol: the paired metrics program must return
    // exactly the leading slice of the full state vector.
    let rt = backend();
    let exe = rt.load("tiny/train_step_qrlora_cls").unwrap();
    let spec = exe.spec.clone();
    let layout = spec.layout().unwrap();

    let mut rng = Rng::new(8);
    let mut state = vec![0f32; layout.total];
    for f in &layout.params {
        for i in 0..f.numel() {
            state[f.offset + i] = rng.normal() * 0.05;
        }
    }
    let state_buf = rt.upload_f32(&state, &[layout.total]).unwrap();
    let rest = default_inputs(&rt, &spec, &mut rng);
    let mut args: Vec<&Buffer> = Vec::new();
    for t in &spec.inputs {
        if t.role == Role::State {
            args.push(&state_buf);
        } else {
            args.push(&rest.iter().find(|(n, _)| n == &t.name).unwrap().1);
        }
    }
    let outs = rt.execute(&exe, &args).unwrap();
    drop(args);
    let full = rt.download_f32(&outs[0]).unwrap();
    let len = layout.metrics_len;
    let metrics_exe = rt.load("tiny/metrics_qrlora_cls").unwrap();
    let slice = rt.read_metrics(&metrics_exe, &outs[0]).unwrap();
    assert_eq!(slice.len(), len);
    for (i, (a, b)) in slice.iter().zip(&full[..len]).enumerate() {
        assert_eq!(a, b, "metrics head mismatch at {i}");
    }
}

#[test]
fn buffer_store_binds_and_absorbs() {
    let rt = backend();
    let exe = rt.load("tiny/kernel_base").unwrap();
    let spec = exe.spec.clone();

    let mut store = qrlora::runtime::BufferStore::new();
    let mut rng = Rng::new(44);
    for t in &spec.inputs {
        let v: Vec<f32> = (0..t.numel()).map(|_| rng.normal()).collect();
        store.upload(&rt, t, &HostTensor::F32(v)).unwrap();
    }
    let args = store.bind(&spec).unwrap();
    let outs = rt.execute(&exe, &args).unwrap();
    drop(args);
    let metrics = store.absorb_outputs(&spec, outs);
    assert_eq!(metrics.len(), 1); // 'y' is role=metric
    assert_eq!(metrics[0].0.name, "y");
}

#[test]
fn missing_input_is_reported_by_name() {
    let rt = backend();
    let exe = rt.load("tiny/kernel_base").unwrap();
    let store = qrlora::runtime::BufferStore::new();
    let err = match store.bind(&exe.spec) {
        Ok(_) => panic!("bind succeeded with empty store"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains('x'), "{err}");
}

#[test]
fn manifest_covers_expected_artifacts() {
    let m = Manifest::builtin();
    let rt = backend();
    for key in [
        "tiny/pretrain_step",
        "tiny/train_step_ft_cls",
        "tiny/train_step_lora_cls",
        "tiny/train_step_qrlora_cls",
        "tiny/train_step_qrlora_reg",
        "tiny/eval_fwd_qrlora_cls",
        "small/train_step_qrlora_cls",
    ] {
        let a = m.artifact(key).unwrap();
        assert!(!a.inputs.is_empty());
        assert!(!a.outputs.is_empty());
        if key.contains("step") {
            let layout = a.layout().unwrap();
            assert_eq!(layout.total, layout.metrics_len + 3 * layout.n_params);
            assert_eq!(a.inputs[0].role, Role::State);
            assert_eq!(a.inputs[0].shape, vec![layout.total]);
        }
        // ...and the host backend can actually load every one of them.
        rt.load(key).unwrap();
    }
}

#[test]
fn eval_accepts_train_state_layout() {
    // The eval program's state input must have the same total length as the
    // train program's — that's what lets the live training buffer be
    // evaluated without repacking.
    let m = Manifest::builtin();
    for method in ["ft", "lora", "qrlora"] {
        let tr = m.artifact(&format!("tiny/train_step_{method}_cls")).unwrap();
        let ev = m.artifact(&format!("tiny/eval_fwd_{method}_cls")).unwrap();
        assert_eq!(
            tr.layout().unwrap().total,
            ev.layout().unwrap().total,
            "{method}: train/eval state layout drift"
        );
    }
}

#[test]
fn backend_selection_auto_falls_back_to_host() {
    // A clean checkout has no artifacts directory: auto must yield host.
    let bk = create_backend(
        BackendChoice::Auto,
        std::path::Path::new("definitely-not-an-artifacts-dir"),
    )
    .unwrap();
    assert_eq!(bk.name(), "host");
    // Explicit host always works.
    let bk = create_backend(BackendChoice::Host, std::path::Path::new("artifacts")).unwrap();
    assert_eq!(bk.name(), "host");
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_without_feature_is_a_clear_error() {
    let err = create_backend(BackendChoice::Pjrt, std::path::Path::new("artifacts"))
        .err()
        .expect("pjrt choice must fail without the feature");
    let msg = format!("{err:#}");
    assert!(msg.contains("pjrt"), "{msg}");
}

/// Host-vs-device parity: requires a real xla crate + `make artifacts`.
/// Run with `cargo test --features pjrt -- --ignored`.
#[cfg(feature = "pjrt")]
mod pjrt_parity {
    use super::*;
    use qrlora::runtime::PjrtBackend;
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    #[ignore = "requires real PJRT artifacts (make artifacts) and the real xla crate"]
    fn kernels_match_host_backend() {
        let dev = PjrtBackend::new(&artifacts_dir()).expect("run `make artifacts` first");
        let host = HostBackend::new();
        let mut rng = Rng::new(4242);
        for key in ["tiny/kernel_base", "tiny/kernel_adapter"] {
            let dexe = dev.load(key).unwrap();
            let hexe = host.load(key).unwrap();
            let values: Vec<Vec<f32>> = dexe
                .spec
                .inputs
                .iter()
                .map(|t| (0..t.numel()).map(|_| rng.normal() * 0.3).collect())
                .collect();
            let dargs: Vec<Buffer> = dexe
                .spec
                .inputs
                .iter()
                .zip(&values)
                .map(|(t, v)| dev.upload_f32(v, &t.shape).unwrap())
                .collect();
            let hargs: Vec<Buffer> = hexe
                .spec
                .inputs
                .iter()
                .zip(&values)
                .map(|(t, v)| host.upload_f32(v, &t.shape).unwrap())
                .collect();
            let drefs: Vec<&Buffer> = dargs.iter().collect();
            let hrefs: Vec<&Buffer> = hargs.iter().collect();
            let dout = dev.download_f32(&dev.execute(&dexe, &drefs).unwrap()[0]).unwrap();
            let hout = host.download_f32(&host.execute(&hexe, &hrefs).unwrap()[0]).unwrap();
            let worst = dout
                .iter()
                .zip(&hout)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(worst <= 1e-3, "{key}: host/device divergence {worst}");
        }
    }

    #[test]
    #[ignore = "requires real PJRT artifacts (make artifacts) and the real xla crate"]
    fn train_step_matches_host_backend() {
        let dev = PjrtBackend::new(&artifacts_dir()).expect("run `make artifacts` first");
        let host = HostBackend::new();
        let key = "tiny/train_step_qrlora_cls";
        let dexe = dev.load(key).unwrap();
        let hexe = host.load(key).unwrap();
        let layout = hexe.spec.layout().unwrap();

        let mut rng = Rng::new(77);
        let mut state = vec![0f32; layout.total];
        for f in &layout.params {
            for i in 0..f.numel() {
                state[f.offset + i] = rng.normal() * 0.05;
            }
        }
        // identical non-state inputs on both backends
        let mut host_rng = rng.clone();
        let dinputs = super::default_inputs(&dev, &dexe.spec, &mut rng);
        let hinputs = super::default_inputs(&host, &hexe.spec, &mut host_rng);
        let dstate = dev.upload_f32(&state, &[layout.total]).unwrap();
        let hstate = host.upload_f32(&state, &[layout.total]).unwrap();

        let dargs: Vec<&Buffer> = dexe
            .spec
            .inputs
            .iter()
            .map(|t| {
                if t.role == Role::State {
                    &dstate
                } else {
                    &dinputs.iter().find(|(n, _)| n == &t.name).unwrap().1
                }
            })
            .collect();
        let hargs: Vec<&Buffer> = hexe
            .spec
            .inputs
            .iter()
            .map(|t| {
                if t.role == Role::State {
                    &hstate
                } else {
                    &hinputs.iter().find(|(n, _)| n == &t.name).unwrap().1
                }
            })
            .collect();
        let dnext = dev.download_f32(&dev.execute(&dexe, &dargs).unwrap()[0]).unwrap();
        let hnext = host.download_f32(&host.execute(&hexe, &hargs).unwrap()[0]).unwrap();
        let worst = dnext
            .iter()
            .zip(&hnext)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(worst <= 1e-3, "{key}: post-step state divergence {worst}");
    }
}
