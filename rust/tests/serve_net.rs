//! End-to-end socket tests for the network serving front-end
//! (`serve --listen`, `qrlora::server::net`), driving the *real binary*
//! over real TCP connections:
//!
//! * replies are bit-identical to the in-process [`serve_swap`] oracle,
//!   for both adapter methods (the wire adds nothing and loses nothing:
//!   f32 → f64 → shortest-decimal JSON → f64 → f32 round-trips exactly),
//! * malformed request lines, unknown tasks, and oversized payloads get
//!   explicit error replies without killing the listener,
//! * concurrent clients each get their own answers back,
//! * a full admission queue sheds with an explicit `queue_full` 503 —
//!   never a silent drop or hang.
//!
//! Bit-identity is arranged by construction: the test process trains and
//! publishes the adapters first, so the spawned server warm-starts from
//! the very same store records (asserted via its `3/3 from store` line).

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{self, Receiver};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use qrlora::data::{task, Batcher, Example, Split};
use qrlora::experiments::{ExpConfig, Pipeline};
use qrlora::server::{serve_swap, Request, RouterStats, ServeCore, SERVE_TASKS};
use qrlora::util::json::Json;

/// Serialize the scenarios: each spawns the real binary (which trains or
/// warm-starts three adapters) and drives it over loopback; overlapping
/// them would oversubscribe the box for no coverage gain.
static SERIAL: Mutex<()> = Mutex::new(());

const EXE: &str = env!("CARGO_BIN_EXE_qrlora");

/// Tiny training budget, kept in lockstep with [`budget_cfg`] so the
/// in-process reference and the spawned server resolve identical
/// adapters (the warm-start fingerprint check enforces the match).
const BUDGET: &[&str] = &["--pretrain-steps", "20", "--warmup-steps", "10", "--steps", "10"];

fn budget_cfg() -> ExpConfig {
    ExpConfig { pretrain_steps: 20, warmup_steps: 10, steps: 10, ..ExpConfig::default() }
}

/// Working directory shared by every scenario, never wiped: the spawned
/// servers reuse each other's `runs/` backbone/warm-up caches. Each
/// scenario gets its own adapter-store directory, so correctness never
/// depends on this directory's prior state.
fn shared_cwd() -> PathBuf {
    let dir = std::env::temp_dir().join("qrlora_serve_net_tests").join("shared");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A scenario-private adapter-store directory, wiped on entry.
fn fresh_store(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("qrlora_serve_net_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A spawned `serve --listen` process with its output relayed line-wise
/// (stdout and stderr merged) and the bound address already parsed from
/// its `NET_LISTEN` line.
struct Server {
    child: Child,
    addr: String,
    lines: Receiver<String>,
}

impl Server {
    /// Spawn the binary on an ephemeral port and wait for `NET_LISTEN`.
    /// Fault-plan env vars are scrubbed first so scenarios can't leak
    /// into each other.
    fn spawn(cwd: &Path, store: &str, extra: &[&str], faults: Option<&str>) -> Server {
        let mut cmd = Command::new(EXE);
        cmd.current_dir(cwd)
            .arg("serve")
            .args(["--listen", "127.0.0.1:0"])
            .args(BUDGET)
            .args(["--adapter-store", store])
            .args(extra)
            .env_remove("QRLORA_FAULTS")
            .env_remove("QRLORA_FAULTS_SEED")
            .env_remove("QRLORA_FAULTS_RESTART")
            .env_remove("QRLORA_WORKER_ID")
            .stdout(Stdio::piped())
            .stderr(Stdio::piped());
        if let Some(spec) = faults {
            cmd.env("QRLORA_FAULTS", spec);
        }
        let mut child = cmd.spawn().expect("spawn qrlora serve --listen");
        let (tx, lines) = mpsc::channel::<String>();
        let stdout = child.stdout.take().expect("stdout piped");
        let stderr = child.stderr.take().expect("stderr piped");
        let tx2 = tx.clone();
        std::thread::spawn(move || {
            for line in BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                let _ = tx2.send(line);
            }
        });
        std::thread::spawn(move || {
            for line in BufReader::new(stderr).lines() {
                let Ok(line) = line else { break };
                let _ = tx.send(line);
            }
        });

        // The server trains (or warm-starts) its adapters before it
        // binds, so the deadline covers a cold store on a loaded box.
        let deadline = Instant::now() + Duration::from_secs(600);
        let mut seen: Vec<String> = Vec::new();
        let addr = loop {
            match lines.recv_timeout(Duration::from_millis(200)) {
                Ok(line) => {
                    if let Some(rest) = line.strip_prefix("NET_LISTEN ") {
                        let addr = rest.split_whitespace().next().unwrap_or("").to_string();
                        seen.push(line);
                        break addr;
                    }
                    seen.push(line);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    assert!(
                        Instant::now() < deadline,
                        "server never printed NET_LISTEN; output so far:\n{}",
                        seen.join("\n")
                    );
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    let _ = child.wait();
                    panic!("server exited before NET_LISTEN; output:\n{}", seen.join("\n"));
                }
            }
        };
        // Re-inject what we read while waiting so `drain` sees it all.
        let (replay_tx, replay_rx) = mpsc::channel::<String>();
        for line in seen {
            let _ = replay_tx.send(line);
        }
        std::thread::spawn(move || {
            for line in lines.iter() {
                if replay_tx.send(line).is_err() {
                    break;
                }
            }
        });
        Server { child, addr, lines: replay_rx }
    }

    /// Wait for a clean exit (the budget was met), then return every
    /// output line for assertions.
    fn finish(mut self) -> Vec<String> {
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            match self.child.try_wait().expect("try_wait server") {
                Some(status) => {
                    let out: Vec<String> = self.lines.iter().collect();
                    assert!(
                        status.success(),
                        "server exited with {status}; output:\n{}",
                        out.join("\n")
                    );
                    return out;
                }
                None => {
                    assert!(
                        Instant::now() < deadline,
                        "server did not exit after its budget was met"
                    );
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Kill a deliberately-wedged server and return its output lines.
    fn kill(mut self) -> Vec<String> {
        let _ = self.child.kill();
        let _ = self.child.wait();
        self.lines.iter().collect()
    }
}

/// One native-protocol client connection.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to serve --listen");
        stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn send_raw(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read reply line");
        assert!(n > 0, "server closed the connection instead of replying");
        Json::parse(line.trim_end()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e:#}"))
    }

    fn request(&mut self, line: &str) -> Json {
        self.send_raw(line);
        self.recv()
    }
}

fn request_line(id: usize, task: &str, ex: &Example) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("task", Json::str(task)),
        ("a", Json::arr_num(ex.a.iter().map(|&t| f64::from(t)))),
        ("b", Json::arr_num(ex.b.iter().map(|&t| f64::from(t)))),
        ("genre", Json::num(ex.genre as f64)),
    ])
    .to_string()
}

/// Two dev-split examples per serving task, with globally unique ids.
fn dev_examples(pipe: &mut Pipeline) -> Vec<(usize, &'static str, Example)> {
    let mut out = Vec::new();
    for t in SERVE_TASKS {
        let data = pipe.data(t).unwrap();
        let dev = data.split(Split::Dev);
        for ex in dev.iter().take(2) {
            out.push((out.len(), *t, ex.clone()));
        }
    }
    out
}

fn err_field(doc: &Json, field: &str) -> String {
    doc.get(field).and_then(Json::as_str).unwrap_or("").to_string()
}

/// TCP replies vs the in-process [`serve_swap`] oracle, bit for bit.
fn check_socket_matches_swap(method: &'static str, store_name: &str) {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_store(store_name);
    let store_s = store.display().to_string();

    // In-process reference first: train + publish the adapters, then run
    // the swap-per-request oracle over the same examples the socket
    // client will send.
    let cfg = budget_cfg();
    let mut core = ServeCore::with_method(&cfg, Some(store.as_path()), method).unwrap();
    core.prepare(SERVE_TASKS).unwrap();
    core.flush_publishes();
    let examples = dev_examples(&mut core.pipe);
    let batcher = Batcher::new(&core.preset, false);
    let mut queue: VecDeque<Request> = examples
        .iter()
        .map(|(id, t, ex)| Request { id: *id, task: t.to_string(), example: ex.clone() })
        .collect();
    let mut stats = RouterStats::default();
    let swapped =
        serve_swap(&mut core.session, &batcher, &core.states, &mut queue, &mut stats).unwrap();
    let want: BTreeMap<usize, Vec<f32>> = swapped.into_iter().map(|(r, l)| (r.id, l)).collect();

    // The server warm-starts from the same store records.
    let requests = examples.len().to_string();
    let server = Server::spawn(
        &cwd,
        &store_s,
        &["--method", method, "--requests", requests.as_str()],
        None,
    );
    let mut client = Client::connect(&server.addr);
    let replies: Vec<Json> =
        examples.iter().map(|(id, t, ex)| client.request(&request_line(*id, t, ex))).collect();
    let out = server.finish();
    assert!(
        out.iter().any(|l| l.contains("3/3 from store")),
        "server must warm-start from the published store (else the oracle \
         and the server hold different adapters):\n{}",
        out.join("\n")
    );

    for ((id, t, _), doc) in examples.iter().zip(&replies) {
        assert_eq!(doc.get("id").and_then(Json::as_usize), Some(*id), "id echo in {doc:?}");
        assert_eq!(doc.get("task").and_then(Json::as_str), Some(*t), "task echo in {doc:?}");
        let logits: Vec<f32> = doc
            .req("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let n = task(t).unwrap().n_classes;
        assert_eq!(logits.len(), n, "{method}: reply must carry exactly n_classes logits");
        for (j, got) in logits.iter().enumerate() {
            let w = want[id][j];
            assert_eq!(
                got.to_bits(),
                w.to_bits(),
                "{method}: request {id} logit {j}: socket {got} vs swap {w}"
            );
        }
    }
}

#[test]
fn tcp_replies_bit_identical_to_serve_swap_qrlora() {
    check_socket_matches_swap("qrlora", "store_bits_qrlora");
}

#[test]
fn tcp_replies_bit_identical_to_serve_swap_lora() {
    check_socket_matches_swap("lora", "store_bits_lora");
}

/// Protocol abuse gets explicit error replies and never kills the
/// listener: after garbage, an unknown task, and an oversized line, the
/// same connection still serves a valid request, and an HTTP client on a
/// second connection gets a well-formed `/healthz`.
#[test]
fn malformed_and_oversized_requests_get_errors_without_killing_the_listener() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_store("store_abuse");
    let store_s = store.display().to_string();

    let cfg = budget_cfg();
    let mut pipe = Pipeline::new(&cfg).unwrap();
    let examples = dev_examples(&mut pipe);
    let server = Server::spawn(&cwd, &store_s, &["--requests", "1"], None);

    // HTTP shim on its own connection (does not consume serving budget).
    let mut http = TcpStream::connect(&server.addr).unwrap();
    http.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    http.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut raw = String::new();
    http.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 200 OK\r\n"), "healthz reply: {raw:?}");
    let body = raw.split("\r\n\r\n").nth(1).unwrap_or("");
    let health = Json::parse(body).unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(
        health.get("registered").and_then(Json::as_arr).map(|a| a.len()),
        Some(SERVE_TASKS.len()),
        "all serving tasks must be registered: {body}"
    );

    let mut client = Client::connect(&server.addr);
    let bad = client.request("{oops");
    assert_eq!(err_field(&bad, "error"), "bad_request");
    assert_eq!(bad.get("code").and_then(Json::as_usize), Some(400));

    let unknown = client.request(r#"{"id": 9, "task": "nope", "a": [1]}"#);
    assert_eq!(err_field(&unknown, "error"), "unknown_task");
    assert_eq!(unknown.get("id").and_then(Json::as_usize), Some(9), "id must be echoed");

    let oversized = client.request(&"x".repeat(70 * 1024));
    assert_eq!(err_field(&oversized, "error"), "oversized");
    assert_eq!(oversized.get("code").and_then(Json::as_usize), Some(413));

    // The listener survived all of it: a valid request still serves.
    let (id, t, ex) = &examples[0];
    let ok = client.request(&request_line(*id, t, ex));
    assert_eq!(ok.get("task").and_then(Json::as_str), Some(*t));
    assert!(
        ok.get("logits").and_then(Json::as_arr).map(|a| !a.is_empty()).unwrap_or(false),
        "valid request after abuse must serve: {ok:?}"
    );

    let out = server.finish();
    let report = out
        .iter()
        .find_map(|l| l.strip_prefix("NET_REPORT "))
        .expect("server must print NET_REPORT");
    let report = Json::parse(report).unwrap();
    assert_eq!(report.get("served").and_then(Json::as_usize), Some(1));
    assert_eq!(
        report.get("rejected").and_then(Json::as_usize),
        Some(3),
        "garbage + unknown task + oversized must all be counted: {report:?}"
    );
}

/// Three concurrent clients on their own connections: every reply goes to
/// the client that asked, with its own id and task echoed back.
#[test]
fn concurrent_clients_each_get_their_own_answers() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_store("store_concurrent");
    let store_s = store.display().to_string();

    let cfg = budget_cfg();
    let mut pipe = Pipeline::new(&cfg).unwrap();
    let examples = dev_examples(&mut pipe); // 6 = 3 clients × 2 requests
    let requests = examples.len().to_string();
    let server = Server::spawn(&cwd, &store_s, &["--requests", requests.as_str()], None);

    let mut handles = Vec::new();
    for chunk in examples.chunks(2) {
        let addr = server.addr.clone();
        let chunk: Vec<(usize, &'static str, Example)> = chunk.to_vec();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr);
            for (id, t, ex) in &chunk {
                let doc = client.request(&request_line(*id, t, ex));
                assert_eq!(doc.get("id").and_then(Json::as_usize), Some(*id), "{doc:?}");
                assert_eq!(doc.get("task").and_then(Json::as_str), Some(*t), "{doc:?}");
                let n = task(t).unwrap().n_classes;
                let len = doc.get("logits").and_then(Json::as_arr).map(|a| a.len());
                assert_eq!(len, Some(n), "{doc:?}");
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }
    let out = server.finish();
    let report = out
        .iter()
        .find_map(|l| l.strip_prefix("NET_REPORT "))
        .expect("server must print NET_REPORT");
    let report = Json::parse(report).unwrap();
    assert_eq!(report.get("served").and_then(Json::as_usize), Some(examples.len()));
    assert_eq!(report.get("rejected").and_then(Json::as_usize), Some(0));
}

/// Queue overflow is an explicit `queue_full` 503, never a silent drop or
/// a hang: with the engine wedged (injected fault) and a depth-1 queue,
/// the first request parks in the queue and the second is shed
/// immediately — on a different connection, proving the listener and
/// writers stay live around the dead engine.
#[test]
fn full_queue_sheds_with_explicit_queue_full_reply() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_store("store_overflow");
    let store_s = store.display().to_string();

    let cfg = budget_cfg();
    let mut pipe = Pipeline::new(&cfg).unwrap();
    let examples = dev_examples(&mut pipe);
    let server = Server::spawn(
        &cwd,
        &store_s,
        &["--requests", "1", "--max-queue-depth", "1"],
        Some("net.engine=hang"),
    );

    // First request: admitted, then parked forever behind the hung
    // engine (no reply — that's the point).
    let mut parked = Client::connect(&server.addr);
    let (id0, t0, ex0) = &examples[0];
    parked.send_raw(&request_line(*id0, t0, ex0));

    // Give the admission a moment to land in the queue, then overflow it
    // from a second connection.
    std::thread::sleep(Duration::from_millis(300));
    let mut client = Client::connect(&server.addr);
    let (id1, t1, ex1) = &examples[1];
    let shed = client.request(&request_line(*id1, t1, ex1));
    assert_eq!(err_field(&shed, "error"), "queue_full", "reply: {shed:?}");
    assert_eq!(shed.get("code").and_then(Json::as_usize), Some(503));
    assert_eq!(shed.get("id").and_then(Json::as_usize), Some(*id1), "id must be echoed");

    let out = server.kill();
    assert!(
        out.iter().any(|l| l.contains("FAULT: injected hang at net.engine")),
        "the engine hang must actually fire:\n{}",
        out.join("\n")
    );
}

/// One HTTP GET on its own connection (the shim serves one request per
/// connection), returning (status line, body).
fn http_get(addr: &str, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    stream
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let status = raw.lines().next().unwrap_or("").to_string();
    let body = raw.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

/// The observability surface end to end against a live server: native
/// replies carry nonzero trace ids, `/metrics.json` counters match what
/// the client actually sent, the Prometheus text parses, `/flight` holds
/// the admit→queue→execute→write span chain for every traced request,
/// and `/healthz` is enriched from the registry — all mid-run, without
/// consuming serving budget.
#[test]
fn metrics_scrape_and_trace_propagation_during_serving() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_store("store_obs");
    let store_s = store.display().to_string();

    let cfg = budget_cfg();
    let mut pipe = Pipeline::new(&cfg).unwrap();
    let examples = dev_examples(&mut pipe);
    let server = Server::spawn(&cwd, &store_s, &["--requests", "3"], None);

    // Two served requests, replies in hand ⇒ their metrics and spans are
    // already published (the engine counts before sending).
    let mut client = Client::connect(&server.addr);
    let mut traces = Vec::new();
    for (id, t, ex) in examples.iter().take(2) {
        let doc = client.request(&request_line(*id, t, ex));
        let trace = doc.get("trace").and_then(Json::as_usize).unwrap_or(0);
        assert!(trace > 0, "served replies must carry a nonzero trace id: {doc:?}");
        traces.push(trace);
    }
    assert_ne!(traces[0], traces[1], "trace ids must be per-request unique");

    let (status, body) = http_get(&server.addr, "/metrics.json");
    assert!(status.contains("200 OK"), "{status}");
    let snap = Json::parse(&body).unwrap();
    let counters = snap.req("counters").unwrap();
    assert_eq!(
        counters.get("net.requests{code=\"ok\"}").and_then(Json::as_usize),
        Some(2),
        "ok counter must match the two served requests: {body}"
    );
    let hists = snap.req("hists").unwrap();
    assert_eq!(
        hists.get("net.request_ms").and_then(|h| h.get("count")).and_then(Json::as_usize),
        Some(2),
        "server-side latency histogram must hold both samples: {body}"
    );

    let (status, text) = http_get(&server.addr, "/metrics");
    assert!(status.contains("200 OK"), "{status}");
    assert!(
        text.contains("qrlora_net_requests{code=\"ok\"} 2"),
        "Prometheus text must carry the ok counter:\n{text}"
    );
    assert!(
        text.contains("qrlora_net_request_ms_bucket"),
        "Prometheus text must carry histogram buckets:\n{text}"
    );

    let (status, body) = http_get(&server.addr, "/flight");
    assert!(status.contains("200 OK"), "{status}");
    let flight = Json::parse(&body).unwrap();
    assert_eq!(flight.get("reason").and_then(Json::as_str), Some("on-demand"));
    let spans = flight.req("spans").unwrap().as_arr().unwrap().clone();
    for trace in &traces {
        let stages: Vec<String> = spans
            .iter()
            .filter(|s| s.get("trace").and_then(Json::as_usize) == Some(*trace))
            .filter_map(|s| s.get("stage").and_then(Json::as_str).map(str::to_string))
            .collect();
        for want in ["admit", "queue", "execute", "write"] {
            assert!(
                stages.iter().any(|s| s == want),
                "trace {trace} must have a {want:?} span, got {stages:?}"
            );
        }
    }

    let (status, body) = http_get(&server.addr, "/healthz");
    assert!(status.contains("200 OK"), "{status}");
    let health = Json::parse(&body).unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    for field in ["bank_resident", "store_generation", "degraded"] {
        assert!(
            health.get(field).and_then(Json::as_f64).is_some(),
            "healthz must carry registry-backed field {field:?}: {body}"
        );
    }

    // None of the scrapes consumed budget: the third native request is
    // still served, and the final report counts exactly three.
    let (id, t, ex) = &examples[2];
    let doc = client.request(&request_line(*id, t, ex));
    assert!(doc.get("trace").and_then(Json::as_usize).unwrap_or(0) > traces[1]);
    let out = server.finish();
    let report = out
        .iter()
        .find_map(|l| l.strip_prefix("NET_REPORT "))
        .expect("server must print NET_REPORT");
    let report = Json::parse(report).unwrap();
    assert_eq!(report.get("served").and_then(Json::as_usize), Some(3));
}
