//! Property tests for the slot-aware admission queue
//! (`qrlora::server::queue`): seeded randomized arrival orders (no
//! wall-clock, no OS randomness — `qrlora::util::rng`) driven through
//! interleaved push bursts and pops, with an external model checking the
//! queue's three contracts after every batch:
//!
//! * **per-connection FIFO** — two requests of the same connection are
//!   never reordered,
//! * **bounded starvation** — no queued entry is ever overtaken by later
//!   arrivals more than `window` times (measured externally by replaying
//!   pop events against arrival order, not by trusting the queue's own
//!   counters),
//! * **conservation** — every generated request is either admitted and
//!   eventually popped, or explicitly handed back by `push` (shed);
//!   the queue always drains to empty.

use std::collections::{BTreeMap, BTreeSet};

use qrlora::server::queue::{AdmissionQueue, QueueConfig, Slotted};
use qrlora::util::rng::Rng;

#[derive(Clone, Debug)]
struct Item {
    conn: u64,
    arrival: usize,
    task: String,
}

impl Slotted for Item {
    fn conn(&self) -> u64 {
        self.conn
    }
    fn task(&self) -> &str {
        &self.task
    }
}

/// Drive one seeded scenario to completion, asserting the invariants
/// after every popped batch. Returns `(admitted, shed)`.
fn run_scenario(
    seed: u64,
    window: usize,
    max_depth: usize,
    max_distinct: usize,
    n: usize,
) -> (usize, usize) {
    let tasks = ["a", "b", "c", "d", "e"];
    let mut rng = Rng::new(seed);
    let mut q: AdmissionQueue<Item> =
        AdmissionQueue::new(QueueConfig { window, max_depth, max_distinct });

    let mut next_arrival = 0usize;
    // Admitted-and-still-queued arrivals, in arrival order (mirrors the
    // queue's internal order without peeking at it).
    let mut queued: Vec<usize> = Vec::new();
    // External overtake ledger, per queued arrival.
    let mut overtaken: BTreeMap<usize, usize> = BTreeMap::new();
    let mut last_popped_per_conn: BTreeMap<u64, usize> = BTreeMap::new();
    let mut last_popped_global: Option<usize> = None;
    let (mut admitted, mut shed, mut popped) = (0usize, 0usize, 0usize);

    while next_arrival < n || !q.is_empty() {
        let push_burst = next_arrival < n && (q.is_empty() || rng.below(3) > 0);
        if push_burst {
            for _ in 0..1 + rng.below(6) {
                if next_arrival >= n {
                    break;
                }
                let item = Item {
                    conn: rng.below(4) as u64,
                    arrival: next_arrival,
                    task: tasks[rng.below(tasks.len())].to_string(),
                };
                match q.push(item) {
                    Ok(()) => {
                        queued.push(next_arrival);
                        overtaken.insert(next_arrival, 0);
                        admitted += 1;
                    }
                    Err(back) => {
                        assert_eq!(back.arrival, next_arrival, "push must hand back the item");
                        shed += 1;
                    }
                }
                next_arrival += 1;
            }
            continue;
        }

        let batch = q.pop_batch(1 + rng.below(4));
        assert!(!batch.is_empty(), "pop on a non-empty queue must make progress");
        let batch_arrivals: BTreeSet<usize> = batch.iter().map(|i| i.arrival).collect();

        // Slot budget: a batch never spans more distinct tasks than the
        // adapter bank can pin.
        let distinct: BTreeSet<&str> = batch.iter().map(|i| i.task.as_str()).collect();
        assert!(
            distinct.len() <= max_distinct,
            "batch spans {} tasks, budget {max_distinct}",
            distinct.len()
        );

        for it in &batch {
            // Per-connection FIFO across the whole run.
            if let Some(&prev) = last_popped_per_conn.get(&it.conn) {
                assert!(
                    prev < it.arrival,
                    "conn {}: arrival {} popped after {prev} (seed {seed}, window {window})",
                    it.conn,
                    it.arrival
                );
            }
            last_popped_per_conn.insert(it.conn, it.arrival);
            // window = 0 degrades to strict global FIFO.
            if window == 0 {
                if let Some(prev) = last_popped_global {
                    assert!(prev < it.arrival, "window 0 reordered: {prev} before {}", it.arrival);
                }
                last_popped_global = Some(it.arrival);
            }
        }

        // Starvation bound, measured externally: every still-queued entry
        // is overtaken once per popped entry that arrived after it.
        for &y in &queued {
            if batch_arrivals.contains(&y) {
                continue;
            }
            let jumps = batch_arrivals.iter().filter(|&&p| p > y).count();
            let total = overtaken.entry(y).or_insert(0);
            *total += jumps;
            assert!(
                *total <= window,
                "arrival {y} overtaken {total} times, window {window} (seed {seed})"
            );
        }
        queued.retain(|a| !batch_arrivals.contains(a));
        popped += batch.len();
    }

    assert!(q.is_empty() && queued.is_empty(), "queue must drain to empty");
    assert_eq!(popped, admitted, "every admitted request must be popped exactly once");
    assert_eq!(admitted + shed, n, "every generated request is admitted or explicitly shed");
    (admitted, shed)
}

/// Randomized seeded arrival orders across the window settings the CLI
/// exposes, deep queue (no shedding): all invariants hold and everything
/// drains.
#[test]
fn randomized_arrivals_respect_fifo_starvation_and_conservation() {
    for window in [0usize, 1, 3, 8] {
        for seed in 0..5u64 {
            let s = 0xC0FFEE ^ (seed * 31) ^ window as u64;
            let (admitted, shed) = run_scenario(s, window, 256, 2, 200);
            assert_eq!(admitted, 200, "depth 256 must never shed 200 requests");
            assert_eq!(shed, 0);
        }
    }
}

/// A shallow queue under bursty arrivals must shed — and the shed path
/// must conserve requests (handed back, never dropped) while the
/// invariants keep holding for everything admitted.
#[test]
fn shallow_queue_sheds_explicitly_and_conserves_requests() {
    let mut total_shed = 0usize;
    for seed in 0..4u64 {
        let (_, shed) = run_scenario(0x5EED ^ seed, 3, 4, 2, 150);
        total_shed += shed;
    }
    assert!(total_shed > 0, "depth 4 under bursts of up to 6 must shed at least once");
}

/// Single-slot budget with many tasks: batches stay single-task, yet the
/// queue still drains under every window setting.
#[test]
fn single_slot_budget_still_drains() {
    for window in [0usize, 2, 8] {
        run_scenario(0xBADD ^ window as u64, window, 64, 1, 120);
    }
}
