//! Rank-selection & interpretability demo (the paper's §2.2/§3.1 story):
//! pivoted-QR diagonal spectra of pretrained vs random weight matrices, and
//! the τ → retained-rank curves under both selection rules.
//!
//! ```text
//! cargo run --release --example rank_selection [--preset tiny]
//! ```

use qrlora::experiments::{ExpConfig, Pipeline};
use qrlora::linalg::{pivoted_qr, select_rank, RankRule};
use qrlora::tensor::Tensor;
use qrlora::util::cli::Args;
use qrlora::util::rng::Rng;

fn spectrum_line(diag: &[f32], width: usize) -> String {
    let max = diag.iter().map(|d| d.abs()).fold(f32::MIN_POSITIVE, f32::max);
    diag.iter()
        .take(width)
        .map(|d| {
            let frac = d.abs() / max;
            match (frac * 8.0) as usize {
                0 => '·',
                1 => '▁',
                2 => '▂',
                3 => '▃',
                4 => '▄',
                5 => '▅',
                6 => '▆',
                7 => '▇',
                _ => '█',
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let cfg = ExpConfig {
        preset: args.str_or("preset", "tiny").to_string(),
        pretrain_steps: args.usize_or("pretrain-steps", 600)?,
        ..ExpConfig::default()
    };
    let mut pipe = Pipeline::new(&cfg)?;
    let bb = pipe.backbone()?;
    let d = pipe.preset.d_model;

    println!("== pivoted-QR diagonal spectra (|R_ii|, descending) ==\n");
    let mut rng = Rng::new(0);
    let random = Tensor::randn(&[d, d], &mut rng, 0.05);
    let rand_diag = pivoted_qr(&random).diag();
    println!("random  {:<24} {}", "N(0,.05) baseline", spectrum_line(&rand_diag, 64));
    for (name, w) in bb.iter().filter(|(n, _)| n.contains("/attn/w")) {
        let diag = pivoted_qr(w).diag();
        println!("trained {:<24} {}", name, spectrum_line(&diag, 64));
    }

    println!("\n== τ → retained rank r (both selection rules) ==\n");
    println!("| matrix | rule | τ=0.3 | τ=0.5 | τ=0.7 | τ=0.8 | τ=0.9 |");
    println!("|---|---|---:|---:|---:|---:|---:|");
    let taus = [0.3, 0.5, 0.7, 0.8, 0.9];
    for (name, w) in bb.iter().filter(|(n, _)| n.contains("attn/wq")) {
        let diag = pivoted_qr(w).diag();
        for (rule, rn) in [
            (RankRule::DiagRatio, "diag-ratio (§4.1)"),
            (RankRule::EnergyCumulative, "energy (eq. 4)"),
        ] {
            let ranks: Vec<String> = taus
                .iter()
                .map(|&t| select_rank(&diag, t, rule).to_string())
                .collect();
            println!("| {name} | {rn} | {} |", ranks.join(" | "));
        }
    }

    println!("\n== reconstruction error vs retained rank (Wq, layer 0) ==\n");
    if let Some(w) = bb.get("layer0/attn/wq") {
        let f = pivoted_qr(w);
        println!("| r | relative ‖W - Q_r R̃_r‖_F |");
        println!("|---:|---:|");
        let wn = w.fro_norm();
        for r in [1usize, 2, 4, 8, 16, 32, d].iter().filter(|&&r| r <= d) {
            let (q, rr) = f.truncate(*r);
            let approx = q.matmul(&rr);
            let mut diff = w.clone();
            for (a, b) in diff.data.iter_mut().zip(&approx.data) {
                *a -= b;
            }
            println!("| {r} | {:.4} |", diff.fro_norm() / wn);
        }
    }
    Ok(())
}
