//! Quickstart: the full QR-LoRA pipeline on one task, end to end.
//!
//! ```text
//! cargo run --release --example quickstart [--preset tiny] [--task sst2]
//! ```
//!
//! 1. MLM-pretrain a backbone on the synthetic corpus (cached under runs/).
//! 2. Warm-up full fine-tune on the task (paper protocol).
//! 3. Extract pivoted-QR bases from the frozen backbone, train only λ.
//! 4. Evaluate and compare against full fine-tuning.

use qrlora::adapters::{Proj, Scope};
use qrlora::experiments::{ExpConfig, Pipeline};
use qrlora::linalg::RankRule;
use qrlora::training::{self, FinetuneJob, Method, Methods, TrainConfig};
use qrlora::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let cfg = ExpConfig {
        preset: args.str_or("preset", "tiny").to_string(),
        pretrain_steps: args.usize_or("pretrain-steps", 600)?,
        warmup_steps: args.usize_or("warmup-steps", 500)?,
        steps: args.usize_or("steps", 400)?,
        train_examples: args.usize_or("train-examples", 10_000)?,
        ..ExpConfig::default()
    };
    let task_name = args.str_or("task", "sst2").to_string();

    println!("== QR-LoRA quickstart ({} / {task_name}) ==\n", cfg.preset);
    let mut pipe = Pipeline::new(&cfg)?;
    let preset = pipe.preset.clone();

    println!("[1/4] pretraining backbone ({} steps, cached)…", cfg.pretrain_steps);
    let _ = pipe.backbone()?;

    println!("[2/4] warm-up full fine-tune ({} steps)…", cfg.warmup_steps);
    let (warm_bb, warm_head) = pipe.warmed(&task_name)?;

    println!("[3/4] extracting pivoted-QR bases (τ=0.5, last layers, Wq+Wv)…");
    let scope = Scope::last_layers((preset.n_layers / 3).max(1), &[Proj::Q, Proj::V]);
    let method = Methods::qr_lora(&warm_bb, &preset, scope, 0.5, RankRule::DiagRatio)?;
    if let Method::QrLora(set) = &method {
        println!(
            "      {} adapted matrices, {} trainable λ coefficients",
            set.factors.len(),
            set.trainable_params()
        );
        for (key, f) in &set.factors {
            println!("      {key}: selected rank {} (used {})", f.selected, f.used);
        }
    }

    println!("[4/4] training λ + head ({} steps)…", cfg.steps);
    let data = pipe.data(&task_name)?;
    let tc = TrainConfig {
        steps: cfg.steps,
        lr: cfg.lr_adapter,
        warmup_steps: cfg.steps / 20 + 1,
        train_examples: cfg.train_examples,
        log_every: (cfg.steps / 8).max(1),
    };
    let job = FinetuneJob {
        rt: pipe.rt,
        preset: &cfg.preset,
        task: &data,
        lexicon: &pipe.lexicon,
        backbone: &warm_bb,
        head: Some(&warm_head),
        config: tc.clone(),
        seed: cfg.seed,
    };
    let qr = training::run_finetune(&job, &method)?;

    // Reference: full fine-tuning with the same budget.
    let mut ft_tc = tc;
    ft_tc.lr = cfg.lr_ft;
    let ft_job = FinetuneJob { config: ft_tc, ..job };
    let ft = training::run_finetune(&ft_job, &Method::FullFt)?;

    println!("\n== results ==");
    println!("loss curve (QR-LoRA): {:?}", qr.losses);
    println!(
        "| method  | params | accuracy | f1 |\n|---|---:|---:|---:|\n| QR-LoRA | {} | {:.2}% | {:.2}% |\n| FT      | {} | {:.2}% | {:.2}% |",
        qr.trainable_params,
        100.0 * qr.dev.accuracy,
        100.0 * qr.dev.f1,
        ft.trainable_params,
        100.0 * ft.dev.accuracy,
        100.0 * ft.dev.f1,
    );
    println!(
        "\nQR-LoRA trains {}× fewer parameters.",
        ft.trainable_params / qr.trainable_params.max(1)
    );
    Ok(())
}
