//! Mini Table-3: compare all four methods on a chosen subset of the
//! synthetic GLUE suite.
//!
//! ```text
//! cargo run --release --example glue_sweep -- --tasks sst2,mnli --steps 300
//! ```

use qrlora::experiments::{self, ExpConfig};
use qrlora::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let cfg = ExpConfig {
        preset: args.str_or("preset", "tiny").to_string(),
        pretrain_steps: args.usize_or("pretrain-steps", 600)?,
        warmup_steps: args.usize_or("warmup-steps", 500)?,
        steps: args.usize_or("steps", 300)?,
        train_examples: args.usize_or("train-examples", 5_000)?,
        ..ExpConfig::default()
    };
    let tasks = args.list_str("tasks", &["sst2", "mnli"]);
    let refs: Vec<&str> = tasks.iter().map(|s| s.as_str()).collect();
    experiments::table3(&cfg, &refs)
}
