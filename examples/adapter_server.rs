//! Multi-task adapter serving demo: one shared frozen backbone, per-task
//! QR-LoRA adapters hot-swapped by a batching router.
//!
//! ```text
//! cargo run --release --example adapter_server -- --requests 200
//! ```

use qrlora::experiments::ExpConfig;
use qrlora::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let cfg = ExpConfig {
        preset: args.str_or("preset", "tiny").to_string(),
        pretrain_steps: args.usize_or("pretrain-steps", 600)?,
        warmup_steps: args.usize_or("warmup-steps", 500)?,
        steps: args.usize_or("steps", 150)?,
        ..ExpConfig::default()
    };
    qrlora::server::demo(&cfg, args.usize_or("requests", 200)?)
}
