//! Multi-task adapter serving demo: one shared frozen backbone, per-task
//! QR-LoRA adapters kept resident in an `AdapterBank`, mixed-task batches
//! served in single backbone passes (with the swap-per-request baseline
//! replayed for comparison).
//!
//! ```text
//! cargo run --release --example adapter_server -- --requests 200 \
//!     --max-batch 8 --resident-adapters 8
//! ```

use qrlora::experiments::ExpConfig;
use qrlora::server::ServeConfig;
use qrlora::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["no-warm-start"])?;
    let cfg = ExpConfig {
        preset: args.str_or("preset", "tiny").to_string(),
        pretrain_steps: args.usize_or("pretrain-steps", 600)?,
        warmup_steps: args.usize_or("warmup-steps", 500)?,
        steps: args.usize_or("steps", 150)?,
        ..ExpConfig::default()
    };
    let sc = ServeConfig::from_args(&args)?;
    qrlora::server::demo(&cfg, &sc)
}
