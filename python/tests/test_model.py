"""L2 model correctness: shapes, adapter equivalences, training dynamics.

All step programs follow the single-output state-vector protocol:
arg0/out0 is the flat f32 state [train | m | v | loss | logits...].
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.presets import PRESETS

P = PRESETS["tiny"]


def init_state(layout, t_init, seed=0):
    """Build the flat state vector: params from t_init dict (or random),
    zero moments, zero metrics tail."""
    rng = np.random.default_rng(seed)
    state = np.zeros(layout["total"], np.float32)
    for name, shape, off in layout["params"]:
        size = int(np.prod(shape)) if shape else 1
        if name in t_init:
            state[off:off + size] = np.asarray(t_init[name], np.float32).reshape(-1)
        else:
            state[off:off + size] = (rng.standard_normal(size) * 0.05).astype(np.float32)
    return state


def read_param(state, layout, name):
    for n, shape, off in layout["params"]:
        if n == name:
            size = int(np.prod(shape)) if shape else 1
            return np.asarray(state[off:off + size]).reshape(shape)
    raise KeyError(name)


def read_metric(state, layout, name):
    for n, shape, off in layout["metrics"]:
        if n == name:
            size = int(np.prod(shape)) if shape else 1
            return np.asarray(state[off:off + size]).reshape(shape)
    raise KeyError(name)


def make_rest(ispecs, seed=0, overrides=None):
    """Host values for all non-state inputs, keyed by spec order."""
    rng = np.random.default_rng(seed)
    overrides = overrides or {}
    args = []
    for name, shape, dtype, role in ispecs[1:]:
        if name in overrides:
            args.append(jnp.asarray(overrides[name]))
            continue
        if dtype == "i32":
            hi = P["vocab"] if "input_ids" in name else 2
            if "labels" in name:
                arr = rng.integers(0, 2, size=shape).astype(np.int32)
            else:
                arr = rng.integers(0, hi, size=shape).astype(np.int32)
        elif name == "lr":
            arr = np.float32(1e-3)
        elif name == "t":
            arr = np.float32(1.0)
        elif name.endswith("/mask"):
            arr = np.ones(shape, np.float32)
        elif "attn_mask" in name or "class_mask" in name or "example_w" in name:
            arr = np.ones(shape, np.float32)
        elif "scale" in name:
            arr = np.full(shape, 0.5, np.float32)
        else:
            arr = (rng.standard_normal(shape) * 0.05).astype(np.float32)
        args.append(jnp.asarray(arr))
    return args


@pytest.mark.parametrize("method", ["ft", "lora", "qrlora"])
@pytest.mark.parametrize("head", ["cls", "reg"])
def test_train_step_shapes_and_finite_loss(method, head):
    fn, ispecs, ospecs, layout = model.build_train_step("tiny", method, head)
    state = jnp.asarray(init_state(layout, {}))
    outs = fn(state, *make_rest(ispecs))
    assert len(outs) == 1
    new_state = outs[0]
    assert new_state.shape == (layout["total"],)
    loss = read_metric(new_state, layout, "loss")
    assert np.isfinite(float(loss)), f"{method}/{head} loss not finite"
    logits = read_metric(new_state, layout, "logits")
    assert logits.shape == (P["batch"], P["n_classes"] if head == "cls" else 1)


@pytest.mark.parametrize("method", ["ft", "lora", "qrlora"])
def test_eval_fwd_shapes(method):
    fn, ispecs, ospecs, layout = model.build_eval_fwd("tiny", method, "cls")
    state = jnp.asarray(init_state(layout, {}))
    outs = fn(state, *make_rest(ispecs))
    assert tuple(outs[0].shape) == tuple(ospecs[0][1])


def test_train_then_eval_consistency():
    """eval_fwd on the post-step state must reproduce the training logits
    when fed the same batch (same forward graph, no dropout)."""
    fn_t, ispecs_t, _, layout = model.build_train_step("tiny", "qrlora", "cls")
    fn_e, ispecs_e, _, _ = model.build_eval_fwd("tiny", "qrlora", "cls")
    state = jnp.asarray(init_state(layout, {}, seed=9))
    rest_t = make_rest(ispecs_t, seed=9)
    new_state = fn_t(state, *rest_t)[0]
    # eval on new_state with the same frozen+batch inputs (minus scalars)
    rest_e = rest_t[:len(ispecs_e) - 1]
    logits_eval = np.asarray(fn_e(new_state, *rest_e)[0])
    # One more train step from new_state with t=2 gives training logits
    # computed at the *same* params new_state holds.
    rest_t2 = list(rest_t)
    rest_t2[-1] = jnp.float32(2.0)
    state3 = fn_t(new_state, *rest_t2)[0]
    logits_train = read_metric(state3, layout, "logits")
    np.testing.assert_allclose(logits_eval, logits_train, atol=2e-4, rtol=2e-4)


def test_pretrain_step_decreases_loss():
    fn, ispecs, _, layout = model.build_pretrain_step("tiny")
    rng = np.random.default_rng(2)
    from compile.model import init_backbone
    state = jnp.asarray(init_state(layout, init_backbone(P, seed=3)))
    rest = make_rest(ispecs, seed=2)
    # Proper mlm labels: mask ~15%
    for i, (name, shape, dtype, role) in enumerate(ispecs[1:]):
        if name == "batch/mlm_labels":
            lab = rng.integers(0, P["vocab"], size=shape).astype(np.int32)
            mask = rng.random(shape) < 0.15
            rest[i] = jnp.asarray(np.where(mask, lab, -100).astype(np.int32))
    step = jax.jit(fn)
    losses = []
    rest = list(rest)
    for t in range(1, 6):
        rest[-1] = jnp.float32(t)
        state = step(state, *rest)[0]
        losses.append(float(read_metric(state, layout, "loss")))
    assert losses[-1] < losses[0], losses


def test_qrlora_zero_lambda_matches_frozen_model():
    """λ=0 ⇒ QR-LoRA forward == plain FT forward on the same backbone."""
    fn_qr, ispecs_qr, _, layout_qr = model.build_eval_fwd("tiny", "qrlora", "cls")
    fn_ft, ispecs_ft, _, layout_ft = model.build_eval_fwd("tiny", "ft", "cls")
    rng = np.random.default_rng(3)

    bb = model.init_backbone(P, seed=7)
    hd = model.init_head(P, "cls", seed=8)

    batch = {}
    for name, shape, dtype, role in ispecs_ft[1:]:
        if role == "batch":
            if dtype == "i32":
                hi = P["vocab"] if "input_ids" in name else 2
                batch[name] = rng.integers(0, hi, size=shape).astype(np.int32)
            else:
                batch[name] = np.ones(shape, np.float32)

    # FT state: backbone+head as trainables.
    state_ft = jnp.asarray(init_state(layout_ft, {**bb, **hd}))
    rest_ft = [jnp.asarray(batch[n]) for n, _, _, r in ispecs_ft[1:]]

    # QR state: λ=0 trainables; backbone frozen inputs; random bases.
    lam0 = {n: np.zeros(s, np.float32) for n, s, _ in layout_qr["params"]
            if n.endswith("/lam")}
    state_qr = jnp.asarray(init_state(layout_qr, {**lam0, **hd}))
    rest_qr = []
    for name, shape, dtype, role in ispecs_qr[1:]:
        if name in bb:
            rest_qr.append(jnp.asarray(bb[name]))
        elif name in batch:
            rest_qr.append(jnp.asarray(batch[name]))
        elif name.endswith("/mask"):
            rest_qr.append(jnp.ones(shape, jnp.float32))
        else:  # Q/R bases — arbitrary, must not matter at λ=0
            rest_qr.append(jnp.asarray(rng.standard_normal(shape).astype(np.float32)))

    out_qr = np.asarray(fn_qr(state_qr, *rest_qr)[0])
    out_ft = np.asarray(fn_ft(state_ft, *rest_ft)[0])
    np.testing.assert_allclose(out_qr, out_ft, atol=2e-4, rtol=2e-4)


def test_qrlora_mask_freezes_masked_directions():
    fn, ispecs, _, layout = model.build_train_step("tiny", "qrlora", "cls")
    keep = 4
    masks = {}
    for name, shape, dtype, role in ispecs[1:]:
        if name.endswith("/mask"):
            m = np.zeros(shape, np.float32)
            m[:keep] = 1.0
            masks[name] = m
    state0 = init_state(layout, {}, seed=5)
    outs = fn(jnp.asarray(state0), *make_rest(ispecs, seed=5, overrides=masks))
    state1 = np.asarray(outs[0])
    for name, shape, off in layout["params"]:
        if name.endswith("/lam"):
            before = state0[off:off + shape[0]]
            after = state1[off:off + shape[0]]
            np.testing.assert_array_equal(before[keep:], after[keep:],
                                          err_msg=f"{name}: masked λ moved")
            assert not np.allclose(before[:keep], after[:keep]), \
                f"{name}: unmasked λ frozen"


def test_class_mask_blocks_padded_class():
    fn, ispecs, _, layout = model.build_eval_fwd("tiny", "ft", "cls")
    overrides = {"batch/class_mask": np.array([1.0, 1.0, 0.0], np.float32)}
    state = jnp.asarray(init_state(layout, {}, seed=6))
    logits = np.asarray(fn(state, *make_rest(ispecs, seed=6, overrides=overrides))[0])
    assert (logits[:, 2] < -1e8).all()


def test_adam_bias_correction_first_step():
    train = {"w": jnp.asarray(np.array([1.0, -2.0], np.float32))}
    grads = {"w": jnp.asarray(np.array([0.3, -0.7], np.float32))}
    m = {"w": jnp.zeros(2)}
    v = {"w": jnp.zeros(2)}
    new_t, _, _ = model.adam_update(train, grads, m, v, 0.01, 1.0)
    step = np.asarray(new_t["w"]) - np.asarray(train["w"])
    np.testing.assert_allclose(step, -0.01 * np.sign(np.asarray(grads["w"])),
                               atol=1e-4)


def test_state_layout_roundtrip():
    _, _, _, layout = model.build_train_step("tiny", "qrlora", "cls")
    # metrics first (offset 0), then params; offsets strictly increasing
    assert layout["metrics"][0][2] == 0
    offs = [o for _, _, o in layout["params"]]
    assert offs == sorted(offs)
    assert offs[0] == layout["metrics_len"]
    total_params = sum(int(np.prod(s)) if s else 1 for _, s, _ in layout["params"])
    assert total_params == layout["n_params"]
    assert layout["total"] == layout["metrics_len"] + 3 * layout["n_params"]


def test_param_counts_match_formula():
    from compile.presets import n_backbone_params
    bb = model.backbone_specs(P)
    total = sum(int(np.prod(s)) for _, s in bb)
    assert total == n_backbone_params(P)


def test_qrlora_trainable_count_is_tiny():
    """The paper's headline: QR-LoRA trains orders of magnitude fewer
    parameters than FT. Structural check on the tiny preset."""
    _, _, _, lq = model.build_train_step("tiny", "qrlora", "cls")
    _, _, _, lf = model.build_train_step("tiny", "ft", "cls")
    head = sum(int(np.prod(s)) for n, s, _ in lq["params"] if n.startswith("head/"))
    adapters_q = lq["n_params"] - head
    assert adapters_q < lf["n_params"] / 20
