"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; fixed cases pin the shapes the model actually uses.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, ref

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def assert_close(a, b, atol=2e-4, rtol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# Fixed-shape smoke cases (the shapes the model presets actually emit).
# ---------------------------------------------------------------------------

PRESET_SHAPES = [
    # (M, K, N, R): tokens × d_in × d_out × max adapter rank
    (256, 64, 64, 32),    # tiny preset attention proj
    (2048, 128, 128, 64), # small preset attention proj
    (256, 64, 256, 32),   # tiny FFN up-proj
]


@pytest.mark.parametrize("m,k,n,r", PRESET_SHAPES)
def test_fused_adapter_matmul_preset_shapes(m, k, n, r):
    rng = np.random.default_rng(0)
    x, w0, q, rr = rand(rng, m, k), rand(rng, k, n), rand(rng, k, r), rand(rng, r, n)
    lam = rand(rng, r)
    got = fused.fused_adapter_matmul(x, w0, q, rr, lam)
    want = ref.fused_adapter_matmul_ref(x, w0, q, rr, lam)
    assert_close(got, want, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("m,k,n,r", PRESET_SHAPES[:2])
def test_dlam_accumulate_preset_shapes(m, k, n, r):
    rng = np.random.default_rng(1)
    x, q, rr, dy = rand(rng, m, k), rand(rng, k, r), rand(rng, r, n), rand(rng, m, n)
    got = fused.dlam_accumulate(x, q, rr, dy)
    want = ref.dlam_ref(x, q, rr, dy)
    # Accumulation over M rows: scale tolerance with M.
    assert_close(got, want, atol=5e-2 * np.sqrt(m), rtol=1e-3)


def test_matmul_matches_ref():
    rng = np.random.default_rng(2)
    x, w = rand(rng, 96, 48), rand(rng, 48, 80)
    assert_close(fused.matmul(x, w), ref.matmul_ref(x, w), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Hypothesis shape sweeps.
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=96)
small_dims = st.integers(min_value=1, max_value=32)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, r=small_dims, seed=st.integers(0, 2**31 - 1))
def test_fused_adapter_matmul_hypothesis(m, k, n, r, seed):
    rng = np.random.default_rng(seed)
    x, w0, q, rr = rand(rng, m, k), rand(rng, k, n), rand(rng, k, r), rand(rng, r, n)
    lam = rand(rng, r)
    got = fused.fused_adapter_matmul(x, w0, q, rr, lam)
    want = ref.fused_adapter_matmul_ref(x, w0, q, rr, lam)
    assert_close(got, want, atol=2e-3, rtol=2e-3)


@settings(max_examples=20, deadline=None)
@given(m=dims, k=small_dims, n=small_dims, r=small_dims,
       seed=st.integers(0, 2**31 - 1))
def test_dlam_hypothesis(m, k, n, r, seed):
    rng = np.random.default_rng(seed)
    x, q, rr, dy = rand(rng, m, k), rand(rng, k, r), rand(rng, r, n), rand(rng, m, n)
    got = fused.dlam_accumulate(x, q, rr, dy)
    want = ref.dlam_ref(x, q, rr, dy)
    assert_close(got, want, atol=1e-2 * max(1.0, np.sqrt(m)), rtol=2e-3)


@settings(max_examples=20, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    assert_close(fused.matmul(x, w), ref.matmul_ref(x, w), atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# Algebraic properties of the fused contraction.
# ---------------------------------------------------------------------------

def test_zero_lambda_is_base_matmul():
    """λ=0 must leave the base projection bit-exact — the frozen-backbone
    guarantee QR-LoRA relies on for non-adapted layers."""
    rng = np.random.default_rng(3)
    x, w0, q, rr = rand(rng, 32, 16), rand(rng, 16, 24), rand(rng, 16, 8), rand(rng, 8, 24)
    got = fused.fused_adapter_matmul(x, w0, q, rr, jnp.zeros(8))
    want = ref.matmul_ref(x, w0)
    assert_close(got, want, atol=1e-5, rtol=1e-5)


def test_linear_in_lambda():
    rng = np.random.default_rng(4)
    x, w0, q, rr = rand(rng, 16, 8), rand(rng, 8, 8), rand(rng, 8, 4), rand(rng, 4, 8)
    l1, l2 = rand(rng, 4), rand(rng, 4)
    base = ref.matmul_ref(x, w0)
    y1 = fused.fused_adapter_matmul(x, w0, q, rr, l1) - base
    y2 = fused.fused_adapter_matmul(x, w0, q, rr, l2) - base
    y12 = fused.fused_adapter_matmul(x, w0, q, rr, l1 + l2) - base
    assert_close(y12, y1 + y2, atol=1e-3, rtol=1e-3)


def test_full_rank_identity_lambda_reconstructs():
    """With Q,R from an exact factorization W0 = Q·R and λ≡1, the adapter
    doubles the projection: x@(W0 + QR) = 2·x@W0."""
    rng = np.random.default_rng(5)
    w0 = rand(rng, 12, 12)
    qf, rf = jnp.linalg.qr(w0)
    x = rand(rng, 20, 12)
    got = fused.fused_adapter_matmul(x, w0, qf, rf, jnp.ones(12))
    assert_close(got, 2.0 * ref.matmul_ref(x, w0), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Custom-vjp wrappers vs jax.grad of the reference.
# ---------------------------------------------------------------------------

def test_qr_proj_gradients_match_reference():
    rng = np.random.default_rng(6)
    m, k, n, r = 24, 16, 20, 6
    x, w0, q, rr = rand(rng, m, k), rand(rng, k, n), rand(rng, k, r), rand(rng, r, n)
    lam = rand(rng, r)

    def loss_kernel(x, lam):
        return jnp.sum(fused.qr_proj(x, w0, q, rr, lam) ** 2)

    def loss_ref(x, lam):
        return jnp.sum(ref.fused_adapter_matmul_ref(x, w0, q, rr, lam) ** 2)

    gx_k, gl_k = jax.grad(loss_kernel, argnums=(0, 1))(x, lam)
    gx_r, gl_r = jax.grad(loss_ref, argnums=(0, 1))(x, lam)
    assert_close(gx_k, gx_r, atol=5e-3, rtol=5e-3)
    assert_close(gl_k, gl_r, atol=5e-3, rtol=5e-3)


def test_lora_proj_gradients_match_reference():
    rng = np.random.default_rng(7)
    m, k, n, r = 24, 16, 20, 4
    x, w0, a, b = rand(rng, m, k), rand(rng, k, n), rand(rng, k, r), rand(rng, r, n)
    scale = jnp.full((r,), 0.5)

    def loss_kernel(x, a, b):
        return jnp.sum(fused.lora_proj(x, w0, a, b, scale) ** 2)

    def loss_ref(x, a, b):
        return jnp.sum(ref.fused_adapter_matmul_ref(x, w0, a, b, scale) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, a, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, a, b)
    for got, want in zip(gk, gr):
        assert_close(got, want, atol=5e-3, rtol=5e-3)


def test_frozen_factors_get_zero_grads():
    rng = np.random.default_rng(8)
    x, w0, q, rr = rand(rng, 8, 8), rand(rng, 8, 8), rand(rng, 8, 4), rand(rng, 4, 8)
    lam = rand(rng, 4)

    g = jax.grad(lambda w: jnp.sum(fused.qr_proj(x, w, q, rr, lam)), argnums=0)(w0)
    assert float(jnp.max(jnp.abs(g))) == 0.0
