"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its reference here to float32 tolerance (pytest + hypothesis sweep
shapes). They are also used directly by `model.py` tests to cross-check the
custom-vjp wrappers against `jax.grad` of the reference computation.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain x @ w."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def fused_adapter_matmul_ref(x, w0, q, r, lam):
    """The QR-LoRA fused projection.

    y = x @ W0 + ((x @ Q) * lam) @ R

    with W0 (K, N) frozen, Q (K, R), R (R, N), lam (R,). This computes
    x @ (W0 + Q diag(lam) R) without materializing the delta — the paper's
    ΔW = Σ_i λ_i Q_i R_iᵀ evaluated lazily. The *same* contraction serves
    LoRA/SVD-LoRA by binding q=A, r=B, lam=(α/r)·1.
    """
    base = jnp.dot(x, w0, preferred_element_type=jnp.float32)
    xq = jnp.dot(x, q, preferred_element_type=jnp.float32)
    delta = jnp.dot(xq * lam[None, :], r, preferred_element_type=jnp.float32)
    return base + delta


def dlam_ref(x, q, r, dy):
    """Gradient of fused_adapter_matmul w.r.t. lam.

    dλ_i = Σ_m (x @ Q)[m, i] · (dy @ Rᵀ)[m, i]
    """
    xq = jnp.dot(x, q, preferred_element_type=jnp.float32)
    dyr = jnp.dot(dy, r.T, preferred_element_type=jnp.float32)
    return jnp.sum(xq * dyr, axis=0)
