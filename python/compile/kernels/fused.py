"""Layer-1 Pallas kernels: the QR-LoRA fused adapter projection and its
backward-pass companions.

Design notes (TPU mapping, estimated in DESIGN.md §8):

* The hot contraction is ``y = x @ W0 + ((x @ Q) * λ) @ R`` — the base
  projection plus a rank-r correction. The kernel never materializes
  ΔW = Q diag(λ) R; the adapter adds O(r/d) FLOPs and **zero** extra
  HBM round-trips, because (Q, R, λ) are small enough to stay VMEM-resident
  across the whole grid.
* Grid is 2-D over (M-tiles, N-tiles). Each program reads a full-K stripe of
  ``x`` and a full-K column block of ``W0`` — for d_model ≤ 768 and tiles of
  128×128 this is ≈1.1 MB of VMEM, far under the ~16 MB budget, so no K-loop
  is needed and the MXU sees two dense (bm×K)@(K×bn) matmuls plus two skinny
  rank-r ones.
* ``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
  custom-calls, so kernels lower to plain HLO. Block shapes are still chosen
  for the TPU layout (multiples of 8×128) so the same code compiles for real
  hardware.

The same kernel serves LoRA and SVD-LoRA by binding ``q=A, r=B,
lam=(α/r)·𝟙`` — see ``ref.fused_adapter_matmul_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: multiples of the TPU (8, 128) tile. At build time we shrink
# them to the actual problem size when the matrices are smaller.
#
# Perf note (EXPERIMENTS.md §Perf iteration 2): on the CPU interpret target
# the grid lowers to an XLA while-loop, so fewer/larger M-tiles are faster;
# QRLORA_BLOCK_M=512 is used for the shipped CPU artifacts. On real TPU the
# tile must stay VMEM-sized — with (512, K=768) stripes the x-tile alone is
# 1.5 MB, still comfortable, but 128 is the MXU-aligned default we keep for
# TPU lowering.
import os

BLOCK_M = int(os.environ.get("QRLORA_BLOCK_M", "128"))
BLOCK_N = int(os.environ.get("QRLORA_BLOCK_N", "128"))


def _block(dim, preferred):
    """Largest divisor of `dim` that is ≤ preferred (keeps grids exact)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Forward: y = x @ w0 + ((x @ q) * lam) @ r
# ---------------------------------------------------------------------------


def _fused_fwd_kernel(x_ref, w0_ref, q_ref, r_ref, lam_ref, o_ref):
    x = x_ref[...]
    base = jnp.dot(x, w0_ref[...], preferred_element_type=jnp.float32)
    xq = jnp.dot(x, q_ref[...], preferred_element_type=jnp.float32)
    delta = jnp.dot(xq * lam_ref[...][None, :], r_ref[...],
                    preferred_element_type=jnp.float32)
    o_ref[...] = base + delta


@functools.partial(jax.jit, static_argnames=())
def fused_adapter_matmul(x, w0, q, r, lam):
    """Pallas fused adapter projection.

    Args:
      x:   (M, K) activations.
      w0:  (K, N) frozen base weight.
      q:   (K, R) orthonormal basis columns (or LoRA A).
      r:   (R, N) row factors (or LoRA B).
      lam: (R,)  per-direction coefficients (masked upstream).

    Returns:
      (M, N) = x @ (w0 + q·diag(lam)·r).
    """
    m, k = x.shape
    k2, n = w0.shape
    assert k == k2, (x.shape, w0.shape)
    rr = q.shape[1]
    assert q.shape == (k, rr) and r.shape == (rr, n) and lam.shape == (rr,)

    bm = _block(m, BLOCK_M)
    bn = _block(n, BLOCK_N)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _fused_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k, rr), lambda i, j: (0, 0)),
            pl.BlockSpec((rr, bn), lambda i, j: (0, j)),
            pl.BlockSpec((rr,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w0, q, r, lam)


# ---------------------------------------------------------------------------
# Backward helper: dλ_i = Σ_m (x@q)[m,i] * (dy@rᵀ)[m,i]
# Accumulated across M-tiles; (R,) output stays resident.
# ---------------------------------------------------------------------------


def _dlam_kernel(x_ref, q_ref, rt_ref, dy_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = jnp.dot(x_ref[...], q_ref[...], preferred_element_type=jnp.float32)
    dyr = jnp.dot(dy_ref[...], rt_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.sum(xq * dyr, axis=0)


@jax.jit
def dlam_accumulate(x, q, r, dy):
    """Gradient of the fused projection w.r.t. lam. Shapes as in fwd."""
    m, k = x.shape
    rr = q.shape[1]
    n = r.shape[1]
    assert dy.shape == (m, n)
    bm = _block(m, BLOCK_M)
    return pl.pallas_call(
        _dlam_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, rr), lambda i: (0, 0)),
            pl.BlockSpec((n, rr), lambda i: (0, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rr,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((rr,), jnp.float32),
        interpret=True,
    )(x, q, r.T, dy)


# ---------------------------------------------------------------------------
# Generic tiled matmul (used for LoRA's dA/dB outer products).
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


@jax.jit
def matmul(x, w):
    """Tiled (M,K)@(K,N) Pallas matmul with full-K stripes."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm = _block(m, BLOCK_M)
    bn = _block(n, BLOCK_N)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


# ---------------------------------------------------------------------------
# Differentiable wrappers (custom VJP; Pallas has no autodiff).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def qr_proj(x, w0, q, r, lam):
    """QR-LoRA projection, differentiable in (x, lam); w0/q/r frozen."""
    return fused_adapter_matmul(x, w0, q, r, lam)


def _qr_proj_fwd(x, w0, q, r, lam):
    return fused_adapter_matmul(x, w0, q, r, lam), (x, w0, q, r, lam)


def _qr_proj_bwd(res, dy):
    x, w0, q, r, lam = res
    # dx = dy@w0ᵀ + ((dy@rᵀ)·λ)@qᵀ — the same fused contraction, transposed.
    dx = fused_adapter_matmul(dy, w0.T, r.T, q.T, lam)
    dlam = dlam_accumulate(x, q, r, dy)
    return dx, jnp.zeros_like(w0), jnp.zeros_like(q), jnp.zeros_like(r), dlam


qr_proj.defvjp(_qr_proj_fwd, _qr_proj_bwd)


@jax.custom_vjp
def lora_proj(x, w0, a, b, scale):
    """LoRA projection y = x@w0 + ((x@a)·scale)@b, differentiable in
    (x, a, b); w0 frozen, scale (R,) a constant vector (α/r, possibly
    masked to disable the adapter entirely)."""
    return fused_adapter_matmul(x, w0, a, b, scale)


def _lora_proj_fwd(x, w0, a, b, scale):
    return fused_adapter_matmul(x, w0, a, b, scale), (x, w0, a, b, scale)


def _lora_proj_bwd(res, dy):
    x, w0, a, b, scale = res
    dx = fused_adapter_matmul(dy, w0.T, b.T, a.T, scale)
    dyb = matmul(dy, b.T) * scale[None, :]  # (M, R)
    da = matmul(x.T, dyb)  # (K, R)
    xa = matmul(x, a) * scale[None, :]  # (M, R)
    db = matmul(xa.T, dy)  # (R, N)
    return dx, jnp.zeros_like(w0), da, db, jnp.zeros_like(scale)


lora_proj.defvjp(_lora_proj_fwd, _lora_proj_bwd)
