"""AOT lowering driver: JAX → HLO text + manifest.json.

Run once at build time (`make artifacts`); the rust coordinator is
self-contained afterwards. HLO *text* is the interchange format — jax ≥ 0.5
serializes HloModuleProto with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

The manifest records, per artifact, the exact ordered input/output specs
(name, shape, dtype, role) so the rust BufferStore can bind buffers by name
and alias outputs back onto inputs between steps.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from compile import model
from compile.presets import PRESETS


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every program in this project returns exactly ONE
    # array (the state-vector protocol), and the rust `execute_b` hot path
    # crashes in xla_extension 0.5.1's ToLiteralSync when the root is a
    # tuple. A plain array root avoids the tuple entirely.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_one(fn, input_specs):
    # keep_unused: the manifest promises the program signature matches the
    # spec list exactly; without it jit drops DCE'd inputs (e.g. labels in
    # eval graphs) and the rust side would feed the wrong arity.
    return jax.jit(fn, keep_unused=True).lower(*model.example_args(input_specs))


def artifact_plan(presets):
    """Yield (key, filename, builder-thunk) for every artifact."""
    plan = []
    for preset in presets:
        def add(kind, builder, preset=preset):
            key = f"{preset}/{kind}"
            plan.append((key, f"{preset}_{kind}.hlo.txt", preset, kind, builder))

        add("pretrain_step", lambda preset=preset: model.build_pretrain_step(preset))
        add("pretrain_metrics",
            lambda preset=preset: model.build_read_metrics(
                model.build_pretrain_step(preset)[3]))
        for method in ("ft", "lora", "qrlora"):
            for head in ("cls", "reg"):
                add(f"train_step_{method}_{head}",
                    lambda preset=preset, m=method, h=head: model.build_train_step(preset, m, h))
                add(f"metrics_{method}_{head}",
                    lambda preset=preset, m=method, h=head: model.build_read_metrics(
                        model.build_train_step(preset, m, h)[3]))
                add(f"eval_fwd_{method}_{head}",
                    lambda preset=preset, m=method, h=head: model.build_eval_fwd(preset, m, h))
        add("kernel_adapter", lambda preset=preset: model.build_kernel_bench(preset, True))
        add("kernel_base", lambda preset=preset: model.build_kernel_bench(preset, False))
    return plan


def spec_json(specs):
    return [
        {"name": n, "shape": list(s), "dtype": d, "role": r}
        for (n, s, d, r) in specs
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small")
    ap.add_argument("--only", default=None, help="substring filter on artifact keys")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    presets = [p.strip() for p in args.presets.split(",") if p.strip()]
    for p in presets:
        if p not in PRESETS:
            sys.exit(f"unknown preset {p!r}")

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    old = {}
    if os.path.exists(manifest_path) and not args.force:
        try:
            with open(manifest_path) as f:
                old = json.load(f).get("artifacts", {})
        except Exception:
            old = {}

    artifacts = {}
    t0 = time.time()
    for key, fname, preset, kind, builder in artifact_plan(presets):
        if args.only and args.only not in key:
            if key in old:
                artifacts[key] = old[key]
            continue
        path = os.path.join(args.out, fname)
        fn, ispecs, ospecs, layout = builder()
        entry = {
            "file": fname,
            "preset": preset,
            "kind": kind,
            "inputs": spec_json(ispecs),
            "outputs": spec_json(ospecs),
        }
        if layout is not None:
            entry["state_layout"] = {
                "n_params": layout["n_params"],
                "metrics_len": layout["metrics_len"],
                "total": layout["total"],
                "params": [
                    {"name": n, "shape": list(s), "offset": o}
                    for n, s, o in layout["params"]
                ],
                "metrics": [
                    {"name": n, "shape": list(s), "offset": o}
                    for n, s, o in layout["metrics"]
                ],
            }
        # Skip lowering when the spec signature is unchanged and file exists.
        sig = hashlib.sha256(
            json.dumps(entry, sort_keys=True).encode()
        ).hexdigest()[:16]
        entry["sig"] = sig
        if (not args.force and key in old and old[key].get("sig") == sig
                and os.path.exists(path)):
            artifacts[key] = old[key]
            print(f"[aot] {key}: up to date")
            continue
        t1 = time.time()
        lowered = lower_one(fn, ispecs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        artifacts[key] = entry
        print(f"[aot] {key}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t1:.1f}s")

    manifest = {
        "version": 1,
        "presets": {p: PRESETS[p] for p in presets},
        "adam": {"b1": model.ADAM_B1, "b2": model.ADAM_B2, "eps": model.ADAM_EPS},
        "artifacts": artifacts,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {manifest_path} ({len(artifacts)} artifacts, "
          f"{time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    main()
