"""Layer-2: the JAX transformer encoder + in-graph training step.

Everything the device executes at runtime is defined here and AOT-lowered by
`aot.py`; the rust coordinator only feeds buffers. Three methods share one
model skeleton and differ in which attention projections carry an adapter:

* ``ft``      — every parameter trainable (also used for warm-up).
* ``lora``    — frozen backbone; rank-r A/B adapters on (Wq, Wv). Serves the
                SVD-LoRA baseline too (identical structure; the coordinator
                seeds A/B from singular vectors and sets scale = α/r).
* ``qrlora``  — frozen backbone; per-projection pivoted-QR bases (Q_r, R_r)
                enter as *frozen inputs* and only the λ coefficients train.

Config variation (τ, layer scope, projection set) is expressed through mask
inputs rather than separate graphs, so ONE artifact per (method, head) serves
every configuration in the paper's sweeps.

Train steps carry Adam inside the graph.

**Single-output state-vector protocol.** The PJRT client used by the rust
side returns multi-output programs as one *tuple* buffer, which cannot be
re-fed per-leaf. Every program therefore takes and returns ONE flat f32
"state vector":

    state = [ loss | logits... | train leaves | adam_m | adam_v ]

The train step unpacks leaves from static offsets, computes grads + Adam, and
re-concatenates — so the output buffer *is* the next step's input buffer and
training state never leaves the device. Metrics live at offset 0 so the rust
coordinator reads them with a cheap ranged host copy of the head. `eval_fwd` accepts the same
state layout (ignoring moments/metrics) so the training-state buffer can be
evaluated directly. The manifest records the layout (`state_layout`).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import fused
from compile.presets import (ADAPTED_PROJS_LORA, ADAPTED_PROJS_QR, PRESETS)

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Parameter specs: ordered (name, shape) lists — the manifest contract.
# ---------------------------------------------------------------------------


def backbone_specs(p):
    """Ordered backbone parameter list for preset dict `p`."""
    d, f, v, s = p["d_model"], p["d_ff"], p["vocab"], p["max_seq"]
    specs = [
        ("emb/tok", (v, d)),
        ("emb/pos", (s, d)),
        ("emb/type", (2, d)),
        ("emb/ln_g", (d,)),
        ("emb/ln_b", (d,)),
    ]
    for i in range(p["n_layers"]):
        L = f"layer{i}"
        for proj in ("wq", "wk", "wv", "wo"):
            specs.append((f"{L}/attn/{proj}", (d, d)))
        for bias in ("bq", "bk", "bv", "bo"):
            specs.append((f"{L}/attn/{bias}", (d,)))
        specs += [
            (f"{L}/ln1_g", (d,)),
            (f"{L}/ln1_b", (d,)),
            (f"{L}/ffn/w1", (d, f)),
            (f"{L}/ffn/b1", (f,)),
            (f"{L}/ffn/w2", (f, d)),
            (f"{L}/ffn/b2", (d,)),
            (f"{L}/ln2_g", (d,)),
            (f"{L}/ln2_b", (d,)),
        ]
    specs.append(("mlm/bias", (v,)))
    return specs


def head_specs(p, head):
    d = p["d_model"]
    k = p["n_classes"] if head == "cls" else 1
    return [
        ("head/wp", (d, d)),
        ("head/bp", (d,)),
        ("head/wc", (d, k)),
        ("head/bc", (k,)),
    ]


def qr_adapter_specs(p):
    """(trainable λ, frozen Q/R/mask) specs for QR-LoRA."""
    d, r = p["d_model"], p["r_max"]
    train, frozen = [], []
    for i in range(p["n_layers"]):
        for proj in ADAPTED_PROJS_QR:
            base = f"qr/layer{i}/{proj}"
            train.append((f"{base}/lam", (r,)))
            frozen += [
                (f"{base}/Q", (d, r)),
                (f"{base}/R", (r, d)),
                (f"{base}/mask", (r,)),
            ]
    return train, frozen


def lora_adapter_specs(p):
    """(trainable A/B, frozen scale) specs for LoRA / SVD-LoRA."""
    d, r = p["d_model"], p["r_lora"]
    train, frozen = [], []
    for i in range(p["n_layers"]):
        for proj in ADAPTED_PROJS_LORA:
            base = f"lora/layer{i}/{proj}"
            train += [(f"{base}/A", (d, r)), (f"{base}/B", (r, d))]
            frozen.append((f"{base}/scale", (r,)))
    return train, frozen


def split_specs(p, method, head):
    """Return (trainable_specs, frozen_specs) for a finetune graph."""
    bb = backbone_specs(p)
    hd = head_specs(p, head)
    if method == "ft":
        return bb + hd, []
    if method == "lora":
        at, af = lora_adapter_specs(p)
        return at + hd, bb + af
    if method == "qrlora":
        at, af = qr_adapter_specs(p)
        return at + hd, bb + af
    raise ValueError(method)


def batch_specs(p, head):
    b, s = p["batch"], p["max_seq"]
    k = p["n_classes"] if head == "cls" else 1
    label = ("batch/labels", (b,), "i32") if head == "cls" else ("batch/labels", (b,), "f32")
    return [
        ("batch/input_ids", (b, s), "i32"),
        ("batch/type_ids", (b, s), "i32"),
        ("batch/attn_mask", (b, s), "f32"),
        label,
        ("batch/class_mask", (k,), "f32"),
        ("batch/example_w", (b,), "f32"),
    ]


def mlm_batch_specs(p):
    b, s = p["batch"], p["max_seq"]
    return [
        ("batch/input_ids", (b, s), "i32"),
        ("batch/type_ids", (b, s), "i32"),
        ("batch/attn_mask", (b, s), "f32"),
        ("batch/mlm_labels", (b, s), "i32"),  # -100 = not predicted
    ]


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _proj(params, method, layer, proj, x2d):
    """Adapted or plain projection for layer `layer`, matrix `proj`.

    x2d is (B·S, d). Returns (B·S, d). This is where L1 kernels enter the
    graph: every adapted projection lowers through the fused Pallas kernel.
    """
    w0 = params[f"layer{layer}/attn/{proj}"]
    bias = params[f"layer{layer}/attn/b{proj[1]}"]
    if method == "qrlora" and proj in ADAPTED_PROJS_QR:
        base = f"qr/layer{layer}/{proj}"
        lam = params[f"{base}/lam"] * params[f"{base}/mask"]
        y = fused.qr_proj(x2d, w0, params[f"{base}/Q"], params[f"{base}/R"], lam)
    elif method == "lora" and proj in ADAPTED_PROJS_LORA:
        base = f"lora/layer{layer}/{proj}"
        y = fused.lora_proj(x2d, w0, params[f"{base}/A"], params[f"{base}/B"],
                            params[f"{base}/scale"])
    else:
        y = jnp.dot(x2d, w0, preferred_element_type=jnp.float32)
    return y + bias


def encode(params, p, method, input_ids, type_ids, attn_mask):
    """Transformer encoder → (B, S, d) hidden states."""
    bsz, seq = input_ids.shape
    d, nh = p["d_model"], p["n_heads"]
    dh = d // nh

    h = (params["emb/tok"][input_ids]
         + params["emb/pos"][None, :seq, :]
         + params["emb/type"][type_ids])
    h = layer_norm(h, params["emb/ln_g"], params["emb/ln_b"])

    # additive mask: (B, 1, 1, S)
    amask = (1.0 - attn_mask)[:, None, None, :] * NEG_INF

    for i in range(p["n_layers"]):
        x = layer_norm(h, params[f"layer{i}/ln1_g"], params[f"layer{i}/ln1_b"])
        x2d = x.reshape(bsz * seq, d)
        q = _proj(params, method, i, "wq", x2d).reshape(bsz, seq, nh, dh)
        k = _proj(params, method, i, "wk", x2d).reshape(bsz, seq, nh, dh)
        v = _proj(params, method, i, "wv", x2d).reshape(bsz, seq, nh, dh)
        # (B, nh, S, S)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        att = jax.nn.softmax(att + amask, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz * seq, d)
        h = h + _proj(params, method, i, "wo", ctx).reshape(bsz, seq, d)

        x = layer_norm(h, params[f"layer{i}/ln2_g"], params[f"layer{i}/ln2_b"])
        x2d = x.reshape(bsz * seq, d)
        f1 = jax.nn.gelu(jnp.dot(x2d, params[f"layer{i}/ffn/w1"]) + params[f"layer{i}/ffn/b1"])
        f2 = jnp.dot(f1, params[f"layer{i}/ffn/w2"]) + params[f"layer{i}/ffn/b2"]
        h = h + f2.reshape(bsz, seq, d)
    return h


def task_logits(params, p, method, head, batch):
    """(B, K) task logits from the CLS position."""
    h = encode(params, p, method, batch["batch/input_ids"],
               batch["batch/type_ids"], batch["batch/attn_mask"])
    cls = h[:, 0, :]
    pooled = jnp.tanh(jnp.dot(cls, params["head/wp"]) + params["head/bp"])
    logits = jnp.dot(pooled, params["head/wc"]) + params["head/bc"]
    if head == "cls":
        # class_mask: 1 for valid classes, 0 for padded ones (binary tasks
        # run with K=3 and a masked third class).
        logits = logits + (1.0 - batch["batch/class_mask"])[None, :] * NEG_INF
    return logits


def task_loss(params, p, method, head, batch):
    logits = task_logits(params, p, method, head, batch)
    w = batch["batch/example_w"]
    wsum = jnp.maximum(jnp.sum(w), 1e-6)
    if head == "cls":
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["batch/labels"][:, None], axis=1)[:, 0]
        loss = jnp.sum(nll * w) / wsum
    else:
        pred = logits[:, 0]
        loss = jnp.sum((pred - batch["batch/labels"]) ** 2 * w) / wsum
    return loss, logits


def mlm_loss(params, p, batch):
    """Masked-LM loss for pretraining / warm-up of the backbone."""
    h = encode(params, p, "ft", batch["batch/input_ids"],
               batch["batch/type_ids"], batch["batch/attn_mask"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["emb/tok"]) + params["mlm/bias"]
    labels = batch["batch/mlm_labels"]
    valid = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


# ---------------------------------------------------------------------------
# Adam (in-graph).
# ---------------------------------------------------------------------------


def global_norm_clip(grads, max_norm=1.0):
    """Scale the whole gradient dict so its global L2 norm is ≤ max_norm."""
    sq = sum(jnp.sum(g * g) for g in grads.values())
    norm = jnp.sqrt(sq + 1e-12)
    scale = jnp.minimum(1.0, max_norm / norm)
    return {k: g * scale for k, g in grads.items()}


def adam_update(train, grads, m, v, lr, t):
    """One Adam step over dicts of arrays (with global-norm gradient
    clipping). `t` is the 1-based step (f32)."""
    grads = global_norm_clip(grads)
    b1t = 1.0 - ADAM_B1 ** t
    b2t = 1.0 - ADAM_B2 ** t
    new_t, new_m, new_v = {}, {}, {}
    for k in train:
        g = grads[k]
        mk = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        vk = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g * g
        mhat = mk / b1t
        vhat = vk / b2t
        new_t[k] = train[k] - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        new_m[k] = mk
        new_v[k] = vk
    return new_t, new_m, new_v


# ---------------------------------------------------------------------------
# Step builders — flat-argument functions ready for jax.jit(...).lower().
# ---------------------------------------------------------------------------


def _dtype_of(spec):
    return spec[2] if len(spec) > 2 else "f32"


def _np_dtype(d):
    return {"f32": jnp.float32, "i32": jnp.int32}[d]


def state_layout(t_specs, metric_specs):
    """Flat state-vector layout: metrics FIRST, then train leaves ×3.

        state = [ metrics | params (P) | adam_m (P) | adam_v (P) ]

    Metrics live at offset 0 so the rust side can read them with a cheap
    ranged device→host copy (`CopyRawToHost` takes a byte offset but the
    crate's bounds check counts elements — offset 0 is the only portable
    choice, see runtime/mod.rs).

    Returns {"metrics": [(name, shape, offset)], "params": [...],
             "n_params": P, "metrics_len": M, "total": M + 3P}.
    """
    metrics = []
    off = 0
    for n, s in metric_specs:
        metrics.append((n, s, off))
        off += int(np.prod(s)) if s else 1
    metrics_len = off
    params = []
    for n, s in t_specs:
        params.append((n, s, off))
        off += int(np.prod(s)) if s else 1
    n_params = off - metrics_len
    return {
        "params": params,
        "n_params": n_params,
        "metrics": metrics,
        "metrics_len": metrics_len,
        "total": metrics_len + 3 * n_params,
    }


def _unpack(state, specs, base):
    """Slice leaves out of the flat state vector from static offsets."""
    out = {}
    off = base
    for n, s in specs:
        size = int(np.prod(s)) if s else 1
        out[n] = state[off:off + size].reshape(s)
        off += size
    return out


def _pack(layout, train, m, v, metric_vals):
    leaves = [val.reshape(-1) for val in metric_vals]
    for n, _, _ in layout["params"]:
        leaves.append(train[n].reshape(-1))
    for n, _, _ in layout["params"]:
        leaves.append(m[n].reshape(-1))
    for n, _, _ in layout["params"]:
        leaves.append(v[n].reshape(-1))
    return jnp.concatenate(leaves)


def build_train_step(preset, method, head):
    """Returns (fn, input_specs, output_specs, layout).

    Single-output protocol: arg0 / out0 is the flat state vector (see module
    docstring); remaining inputs are frozen constants, batch tensors, and
    the (lr, t) scalars.
    """
    p = PRESETS[preset]
    t_specs, f_specs = split_specs(p, method, head)
    b_specs = batch_specs(p, head)
    k = p["n_classes"] if head == "cls" else 1
    metric_specs = [("loss", ()), ("logits", (p["batch"], k))]
    layout = state_layout(t_specs, metric_specs)
    total = layout["total"]
    n_params = layout["n_params"]
    mlen = layout["metrics_len"]

    input_specs = (
        [("state", (total,), "f32", "state")]
        + [(n, s, "f32", "frozen") for n, s in f_specs]
        + [(n, s, d, "batch") for n, s, d in b_specs]
        + [("lr", (), "f32", "scalar"), ("t", (), "f32", "scalar")]
    )
    output_specs = [("state", (total,), "f32", "state")]
    nf, nb = len(f_specs), len(b_specs)

    def step(*args):
        state = args[0]
        frozen = {n: a for (n, _), a in zip(f_specs, args[1:1 + nf])}
        batch = {n: a for (n, _, _), a in zip(b_specs, args[1 + nf:1 + nf + nb])}
        lr, t = args[1 + nf + nb], args[2 + nf + nb]

        train = _unpack(state, t_specs, mlen)
        m = _unpack(state, t_specs, mlen + n_params)
        v = _unpack(state, t_specs, mlen + 2 * n_params)

        def loss_fn(tr):
            loss, logits = task_loss({**tr, **frozen}, p, method, head, batch)
            return loss, logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(train)
        new_t, new_m, new_v = adam_update(train, grads, m, v, lr, t)
        return (_pack(layout, new_t, new_m, new_v, [loss, logits]),)

    return step, input_specs, output_specs, layout


def build_eval_fwd(preset, method, head):
    """Forward-only program. Accepts the *training* state vector layout so
    the live training buffer can be evaluated without repacking."""
    p = PRESETS[preset]
    t_specs, f_specs = split_specs(p, method, head)
    b_specs = batch_specs(p, head)
    k = p["n_classes"] if head == "cls" else 1
    metric_specs = [("loss", ()), ("logits", (p["batch"], k))]
    layout = state_layout(t_specs, metric_specs)

    input_specs = (
        [("state", (layout["total"],), "f32", "state")]
        + [(n, s, "f32", "frozen") for n, s in f_specs]
        + [(n, s, d, "batch") for n, s, d in b_specs]
    )
    output_specs = [("logits", (p["batch"], k), "f32", "metric")]
    nf, nb = len(f_specs), len(b_specs)

    def fwd(*args):
        state = args[0]
        frozen = {n: a for (n, _), a in zip(f_specs, args[1:1 + nf])}
        batch = {n: a for (n, _, _), a in zip(b_specs, args[1 + nf:1 + nf + nb])}
        train = _unpack(state, t_specs, layout["metrics_len"])
        return (task_logits({**train, **frozen}, p, method, head, batch),)

    return fwd, input_specs, output_specs, layout


def build_pretrain_step(preset):
    """MLM step: the whole backbone trains (no task head)."""
    p = PRESETS[preset]
    t_specs = backbone_specs(p)
    b_specs = mlm_batch_specs(p)
    metric_specs = [("loss", ())]
    layout = state_layout(t_specs, metric_specs)
    total = layout["total"]
    n_params = layout["n_params"]
    mlen = layout["metrics_len"]

    input_specs = (
        [("state", (total,), "f32", "state")]
        + [(n, s, d, "batch") for n, s, d in b_specs]
        + [("lr", (), "f32", "scalar"), ("t", (), "f32", "scalar")]
    )
    output_specs = [("state", (total,), "f32", "state")]
    nb = len(b_specs)

    def step(*args):
        state = args[0]
        batch = {n: a for (n, _, _), a in zip(b_specs, args[1:1 + nb])}
        lr, t = args[1 + nb], args[2 + nb]
        train = _unpack(state, t_specs, mlen)
        m = _unpack(state, t_specs, mlen + n_params)
        v = _unpack(state, t_specs, mlen + 2 * n_params)

        loss, grads = jax.value_and_grad(lambda tr: mlm_loss(tr, p, batch))(train)
        new_t, new_m, new_v = adam_update(train, grads, m, v, lr, t)
        return (_pack(layout, new_t, new_m, new_v, [loss]),)

    return step, input_specs, output_specs, layout


def build_read_metrics(layout):
    """Tiny slice program: state -> metrics head. The PJRT CPU client has no
    ranged host copy (CopyRawToHost not implemented), so the coordinator
    reads per-step metrics by running this on-device slice and downloading
    only its (small) output."""
    total, mlen = layout["total"], layout["metrics_len"]
    input_specs = [("state", (total,), "f32", "state")]
    output_specs = [("metrics", (mlen,), "f32", "metric")]

    def fn(state):
        return (state[:mlen],)

    return fn, input_specs, output_specs, layout


def build_kernel_bench(preset, with_adapter):
    """Micro artifact: one fused projection (or plain matmul) at the
    preset's hot shape — used by the rust benches to measure adapter
    overhead through the full PJRT path."""
    p = PRESETS[preset]
    mm = p["batch"] * p["max_seq"]
    d, r = p["d_model"], p["r_max"]
    if with_adapter:
        input_specs = [
            ("x", (mm, d), "f32", "batch"),
            ("w0", (d, d), "f32", "frozen"),
            ("Q", (d, r), "f32", "frozen"),
            ("R", (r, d), "f32", "frozen"),
            ("lam", (r,), "f32", "train"),
        ]

        def fn(x, w0, q, rr, lam):
            return (fused.fused_adapter_matmul(x, w0, q, rr, lam),)
    else:
        input_specs = [
            ("x", (mm, d), "f32", "batch"),
            ("w0", (d, d), "f32", "frozen"),
        ]

        def fn(x, w0):
            return (fused.matmul(x, w0),)

    output_specs = [("y", (mm, d), "f32", "metric")]
    return fn, input_specs, output_specs, None


def example_args(input_specs):
    """ShapeDtypeStructs for jax.jit(...).lower(*...)."""
    return [jax.ShapeDtypeStruct(tuple(s), _np_dtype(d)) for _, s, d, _ in input_specs]


# ---------------------------------------------------------------------------
# Host-side init (used by python tests; the rust side re-implements this
# with the same formulas, keyed by the manifest's init hints).
# ---------------------------------------------------------------------------


def init_backbone(p, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    d = p["d_model"]
    for name, shape in backbone_specs(p):
        if name.endswith(("_g",)) or "/ln_g" in name:
            out[name] = np.ones(shape, np.float32)
        elif name.endswith(("_b", "bias")) or "/b" in name.split("/")[-1]:
            out[name] = np.zeros(shape, np.float32)
        elif len(shape) == 2:
            std = (2.0 / (shape[0] + shape[1])) ** 0.5
            out[name] = rng.standard_normal(shape).astype(np.float32) * std
        else:
            out[name] = np.zeros(shape, np.float32)
    # embeddings: N(0, 0.02) like BERT
    for k in ("emb/tok", "emb/pos", "emb/type"):
        out[k] = rng.standard_normal(out[k].shape).astype(np.float32) * 0.02
    out["emb/ln_g"] = np.ones((d,), np.float32)
    return out


def init_head(p, head, seed=1):
    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in head_specs(p, head):
        if name.endswith(("bp", "bc")):
            out[name] = np.zeros(shape, np.float32)
        else:
            std = (2.0 / sum(shape)) ** 0.5
            out[name] = rng.standard_normal(shape).astype(np.float32) * std
    return out
