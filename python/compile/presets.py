"""Model presets. Mirrored by `rust/src/config/` (the manifest carries these
numbers so the two sides can never drift).

The paper uses RoBERTa-base (d=768, 12 layers). The repro testbed is a single
CPU core, so presets scale the architecture down while preserving every
structural property the method depends on: multi-head attention with four
adaptable projections per layer, a pre-LN residual stack, and a pooled
classification head. Parameter-count *ratios* between methods are preserved
and reported next to the paper's.
"""

PRESETS = {
    # Test-speed preset: used by pytest, cargo integration tests.
    "tiny": dict(
        d_model=64,
        n_layers=2,
        n_heads=2,
        d_ff=256,
        vocab=512,
        max_seq=32,
        batch=8,
        r_max=32,   # max retained QR rank per projection
        r_lora=2,   # LoRA rank (paper: r=2)
        n_classes=3,
    ),
    # Experiment preset: all tables/figures run on this.
    "small": dict(
        d_model=128,
        n_layers=4,
        n_heads=4,
        d_ff=512,
        vocab=4096,
        max_seq=64,
        batch=32,
        r_max=64,
        r_lora=2,
        n_classes=3,
    ),
    # Scale-demonstration preset (quickstart --preset mid): ~8M params.
    "mid": dict(
        d_model=256,
        n_layers=6,
        n_heads=8,
        d_ff=1024,
        vocab=8192,
        max_seq=64,
        batch=16,
        r_max=128,
        r_lora=2,
        n_classes=3,
    ),
}

METHODS = ("ft", "lora", "qrlora")
HEADS = ("cls", "reg")

ADAPTED_PROJS_QR = ("wq", "wk", "wv", "wo")  # QR-LoRA can adapt any of these
ADAPTED_PROJS_LORA = ("wq", "wv")            # LoRA baseline adapts Wq, Wv


def n_backbone_params(p):
    """Total backbone parameter count for a preset dict."""
    d, f, v, s, nl = p["d_model"], p["d_ff"], p["vocab"], p["max_seq"], p["n_layers"]
    emb = v * d + s * d + 2 * d + 2 * d
    per_layer = 4 * (d * d + d) + 2 * d + (d * f + f) + (f * d + d) + 2 * d
    return emb + nl * per_layer + v  # + mlm bias
